"""Batched serving engine with SubGCache prefix-state reuse.

Execution paths:
  * ``prefill_prefix``      — compute the representative prefix state once
                              (batch 1), paper §3.4 step 1.
  * ``generate_with_prefix``— serve all cluster members as ONE batched
                              suffix prefill + greedy decode (TPU
                              adaptation; the paper loops members
                              sequentially).  Attention-only stacks use
                              the **split prefix/suffix cascade**
                              (DESIGN.md §5): members get a suffix+decode
                              cache only, and the live batch-1 prefix
                              buffers are attended in place — HBM for a
                              B-member cluster is P + B×S slots instead
                              of B×(P+S), and prefix KV bytes are read
                              once per kv-head group, not once per
                              member.  Stateful (Mamba / RG-LRU) and
                              cross-attention stacks fall back to
                              ``PrefixState.broadcast`` (their recurrent
                              states are tiny).
  * ``generate_multi_prefix``— pooled ONLINE serving (DESIGN.md §7): one
                              batch mixes members of SEVERAL clusters.
                              The per-cluster ``PrefixState``s are
                              padded to a common capacity and stacked
                              into an [NP, ...] pool; every row carries
                              a prefix index and its own slot offset,
                              so a single prefill + decode step serves
                              all clusters at once — no idling between
                              clusters.  Bit-identical to serving each
                              cluster separately through the cascade.
  * ``generate``            — vanilla per-query path (the baseline).

Timing dicts returned by the serving calls carry aggregate
``prefill_s``/``decode_s`` plus per-member ``prefill_share``/
``decode_share`` lists — sub-batched serving (stateful fallback) costs
each member its OWN sub-batch's share, not a global average.

Shapes are bucketed (suffix length to multiples of ``bucket``, batch to
powers of two) so a handful of compiled executables serve any workload —
lengths are data, not shapes (DESIGN.md §3).
"""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import ClusterCacheManager, PrefixState
from repro.data.tokenizer import EOS, PAD, Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig


def _bucket_len(n: int, bucket: int) -> int:
    """Round a sequence length up to the next multiple of ``bucket``."""
    return max(bucket, ((n + bucket - 1) // bucket) * bucket)


def _bucket_batch(n: int) -> int:
    """Round a batch (or pool) size up to the next power of two."""
    b = 1
    while b < n:
        b *= 2
    return b


class ServingEngine:
    """Executes serving traffic for one model (see module docstring).

    Owns the jitted prefill/decode builders (lru-cached per shape
    bucket), the ``ClusterCacheManager`` that accounts ``CacheStats``,
    and the split-vs-broadcast policy decision.  Tensor conventions
    follow ``kernels/``: embeddings ``[B, T, D]``, positions/valid
    ``[B, T]``, KV caches seq-major ``{"k","v": [B, C, Hkv, Dh],
    "pos": [B, C]}`` with pooled prefixes adding a leading NP dim.

    ``max_cache_len``: hard capacity ceiling per sequence.
    ``max_new_tokens``: greedy-decode budget (EOS stops earlier).
    ``bucket``: suffix-length bucket (lengths are data, shapes are
    buckets — DESIGN.md §3).  ``split_prefix``: force-disable the split
    cascade with ``False`` (A/B comparisons); default auto-enables it
    on attention-only stacks.
    """

    def __init__(self, params, cfg: ModelConfig, tokenizer: Tokenizer, *,
                 max_cache_len: int = 768, max_new_tokens: int = 32,
                 bucket: int = 32, split_prefix: Optional[bool] = None):
        self.params = params
        self.cfg = cfg
        self.tok = tokenizer
        self.max_cache_len = max_cache_len
        self.max_new_tokens = max_new_tokens
        self.bucket = bucket
        self.cache_mgr = ClusterCacheManager()
        self._prefill_jit = functools.lru_cache(maxsize=64)(self._make_prefill)
        self._decode_jit = functools.lru_cache(maxsize=16)(self._make_decode)
        # last stacked multi-prefix pool, keyed on the identity of the
        # stacked states (see _serve_multi_pooled)
        self._pool_stack: Optional[tuple] = None
        # Recurrent mixers (Mamba / RG-LRU) carry state through every
        # consumed token — right-padding would corrupt it (attention masks
        # padded slots; scans cannot).  Such archs get length-exact
        # processing: no pad tokens ever enter the scan.
        from repro.models.config import MAMBA, RGLRU
        self._stateful = any(s.mixer in (MAMBA, RGLRU)
                             for s in cfg.layer_specs())
        # Split prefix/suffix cascade serving (DESIGN.md §5) covers
        # attention-only stacks: recurrent state is not a set of
        # positional slots and cross-attention KV is per-state, so both
        # fall back to PrefixState.broadcast.  ``split_prefix=False``
        # forces the broadcast path (benchmark / A-B comparisons).
        has_cross = any(s.cross_attn for s in cfg.layer_specs())
        can_split = not self._stateful and not has_cross
        self.use_split_prefix = (can_split if split_prefix is None
                                 else bool(split_prefix) and can_split)

    # ------------------------------------------------------------------
    # jitted building blocks (cached per shape bucket)
    # ------------------------------------------------------------------
    def _make_prefill(self, batch: int, seqlen: int):
        """One builder serves all paths: broadcast callers pass
        ``prefix=None`` (empty pytree — same trace as before); split
        callers pass the live batch-1 prefix buffers as an ordinary
        non-donated argument, read in place — no replication, no copy;
        pooled callers pass the stacked [NP, ...] pool plus a per-row
        ``prefix_idx`` [B] and per-row ``slot_offset`` [B]."""
        cfg = self.cfg

        def prefill(params, embeds, positions, valid, cache, prefix,
                    slot_offset, prefix_idx):
            hidden, cache, _ = M.forward(params, cfg, embeds, positions,
                                         cache=cache, valid=valid,
                                         prefix=prefix,
                                         slot_offset=slot_offset,
                                         prefix_idx=prefix_idx)
            lengths = jnp.sum(valid.astype(jnp.int32), axis=1)      # [B]
            last = jnp.take_along_axis(
                hidden, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)
            logits = M.unembed(params, cfg, last)[:, 0]             # [B, V]
            return cache, logits, lengths

        return jax.jit(prefill, donate_argnums=(4,))

    def _make_decode(self, batch: int):
        """In split mode the decode scan closes over the prefix (and the
        pooled ``prefix_idx``) as invariants — never carried, donated,
        or copied per step."""
        cfg = self.cfg
        steps = self.max_new_tokens - 1

        def decode(params, first_token, lengths, cache, prefix, slot_offset,
                   prefix_idx):
            def body(carry, _):
                cache, tok, pos, done = carry
                emb = M.embed_tokens(params, tok[:, None])
                hidden, cache, _ = M.forward(params, cfg, emb, pos[:, None],
                                             cache=cache, prefix=prefix,
                                             slot_offset=slot_offset,
                                             prefix_idx=prefix_idx)
                logits = M.unembed(params, cfg, hidden)[:, 0]
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                done = done | (tok == EOS)
                nxt = jnp.where(done, EOS, nxt)
                return (cache, nxt, pos + 1, done), nxt

            init = (cache, first_token, lengths,
                    jnp.zeros((batch,), bool))
            (cache, _, _, _), toks = jax.lax.scan(body, init, None,
                                                  length=steps)
            return jnp.concatenate([first_token[:, None], toks.T], axis=1)

        return jax.jit(decode, donate_argnums=(3,))

    # ------------------------------------------------------------------
    # embedding helpers
    # ------------------------------------------------------------------
    def _embed_padded(self, token_lists: Sequence[List[int]],
                      soft: Optional[np.ndarray], pos_offset,
                      pad_to: Optional[int] = None):
        """Right-pad token lists (+ optional shared soft-prompt embeds
        prepended) into (embeds [B,T,D], positions [B,T], valid [B,T]).

        ``pos_offset`` shifts the absolute positions: a scalar applies
        to every row (single shared prefix); a [B] array gives each row
        its own start (multi-prefix serving — each row sits behind its
        own cluster's prefix length)."""
        n_soft = 0 if soft is None else soft.shape[0]
        lens = [len(t) + n_soft for t in token_lists]
        t_pad = pad_to or _bucket_len(max(lens), self.bucket)
        b = len(token_lists)
        ids = np.full((b, t_pad), PAD, np.int32)
        valid = np.zeros((b, t_pad), bool)
        for i, toks in enumerate(token_lists):
            ids[i, n_soft:n_soft + len(toks)] = toks
            valid[i, :lens[i]] = True
        embeds = M.embed_tokens(self.params, jnp.asarray(ids))
        if soft is not None:
            embeds = embeds.at[:, :n_soft].set(
                jnp.asarray(soft)[None].astype(embeds.dtype))
        off = jnp.asarray(pos_offset, jnp.int32)
        off = off[:, None] if off.ndim == 1 else off[None, None]
        positions = off + jnp.arange(t_pad, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(positions, (b, t_pad))
        return embeds, positions, jnp.asarray(valid), np.asarray(lens)

    # ------------------------------------------------------------------
    # SubGCache path
    # ------------------------------------------------------------------
    def _bucket_capacity(self, need: int, floor: int, kind: str) -> int:
        """Power-of-two capacity bucket >= ``need``, starting at
        ``floor``, bounded by ``max_cache_len``."""
        cap = min(floor, self.max_cache_len)
        while cap < need:
            cap *= 2
        if cap > self.max_cache_len:
            raise ValueError(
                f"{kind} needs cache capacity {cap} > max_cache_len "
                f"{self.max_cache_len}; raise max_cache_len")
        return cap

    def _capacity_for(self, prefix_len: int, suffix_headroom: int = 64) -> int:
        """Cache capacity bucket covering prefix + suffix + decode."""
        return self._bucket_capacity(
            prefix_len + suffix_headroom + self.max_new_tokens + 8, 512,
            "prompt")

    def _prefix_capacity_for(self, prefix_len: int) -> int:
        """Capacity bucket for a split-mode prefix state: prefix tokens
        only — suffix and decode live in the per-member suffix cache."""
        return self._bucket_capacity(prefix_len, 128, "prefix")

    def _suffix_capacity_for(self, suffix_len: int) -> int:
        """Capacity bucket for the per-member suffix+decode cache."""
        return self._bucket_capacity(
            suffix_len + self.max_new_tokens + 8, 64, "suffix")

    def prefill_prefix(self, prefix_tokens: List[int],
                       soft: Optional[np.ndarray] = None,
                       enc: Optional[np.ndarray] = None,
                       _record: bool = True) -> Tuple[PrefixState, float]:
        """Representative-subgraph prefix prefill at batch=1.

        Split mode sizes the state for the prefix alone (suffix + decode
        slots live in the per-member suffix cache); broadcast mode keeps
        headroom for the suffix prefill + decode that run in this cache.
        """
        t0 = time.perf_counter()
        embeds, positions, valid, lens = self._embed_padded(
            [prefix_tokens], soft, 0,
            pad_to=None if not self._stateful else
            len(prefix_tokens) + (0 if soft is None else soft.shape[0]))
        use_split = self.use_split_prefix and enc is None
        capacity = (self._prefix_capacity_for(int(lens[0])) if use_split
                    else self._capacity_for(int(lens[0])))
        if _record:
            # prefix cost accrues when COMPUTED: a state reused across
            # several generate_with_prefix calls still cost one prefill
            self.cache_mgr.stats.record_prefix(int(lens[0]), split=use_split)
        cache = M.init_cache(self.cfg, 1, capacity,
                             enc_len=0 if enc is None else enc.shape[1])
        prefill = self._prefill_jit(1, embeds.shape[1])
        cache, _, _ = prefill(self.params, embeds, positions, valid, cache,
                              None, 0, None)
        jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        state = PrefixState(cache=cache, prefix_len=int(lens[0]),
                            capacity=capacity,
                            enc_len=0 if enc is None else enc.shape[1])
        return state, dt

    def generate_with_prefix(self, state: PrefixState,
                             suffix_token_lists: Sequence[List[int]],
                             _record: bool = True
                             ) -> Tuple[List[List[int]], dict]:
        """Batched suffix prefill over the shared prefix + greedy decode.

        Attention-only stacks take the split prefix/suffix cascade: a
        suffix+decode cache of B × suffix_capacity slots is allocated and
        the live batch-1 prefix buffers are passed through prefill and
        the decode scan unreplicated (``PrefixState.broadcast`` is never
        called).  Stateful (recurrent) archs fall back to broadcast and
        are served in equal-length sub-batches so no pad token ever
        enters the scan state (exactness)."""
        outs, timing = self._serve_with_prefix(state, suffix_token_lists)
        if _record:
            # members count only once actually served: a capacity error
            # above must not inflate prefill_savings
            stats = self.cache_mgr.stats
            stats.record_served(len(suffix_token_lists))
            for tkl in suffix_token_lists:
                stats.record_member(state.prefix_len + len(tkl), len(tkl))
            stats.finalize()
        return outs, timing

    def generate_multi_prefix(self, states: Sequence[PrefixState],
                              prefix_ids: Sequence[int],
                              suffix_token_lists: Sequence[List[int]],
                              _record: bool = True
                              ) -> Tuple[List[List[int]], dict]:
        """Serve ONE batch whose rows belong to SEVERAL clusters.

        ``states``: the NP distinct cluster ``PrefixState``s this batch
        touches; ``prefix_ids[i]`` indexes the state row ``i`` is served
        against; ``suffix_token_lists[i]`` is row ``i``'s suffix.

        The states are padded to their max capacity and stacked into an
        [NP, ...] pool pytree; each row carries its prefix index (fed to
        the kernels via scalar prefetch) and its own slot offset (its
        cluster's prefix length), so one suffix prefill + one decode
        scan serve every cluster at once (DESIGN.md §7).  Exact: each
        row's math is identical to single-prefix cascade serving.

        Stateful (Mamba / RG-LRU) and cross-attention stacks cannot
        split a positional prefix, so they fall back to per-cluster
        ``generate_with_prefix`` calls with stitched per-member timing.

        Returns ``(outputs, timing)`` like ``generate_with_prefix``,
        with ``timing["num_prefixes"] = NP``.
        """
        n = len(suffix_token_lists)
        assert len(prefix_ids) == n, (len(prefix_ids), n)
        assert all(0 <= p < len(states) for p in prefix_ids)
        if self._stateful or any(st.enc_len for st in states) \
                or not self.use_split_prefix:
            outs, timing = self._serve_multi_grouped(states, prefix_ids,
                                                     suffix_token_lists)
        elif len(states) == 1:
            # single-cluster micro-batch (common under temporally
            # clustered traffic): the batch-1 prefix buffers are served
            # in place — no stacked device copy, and the single-prefix
            # compiled executables are reused
            outs, timing = self._serve_with_prefix(states[0],
                                                   suffix_token_lists)
            timing["num_prefixes"] = 1
        else:
            outs, timing = self._serve_multi_pooled(states, prefix_ids,
                                                    suffix_token_lists)
        if _record:
            stats = self.cache_mgr.stats
            stats.record_served(n)
            for pid, tkl in zip(prefix_ids, suffix_token_lists):
                stats.record_member(states[pid].prefix_len + len(tkl),
                                    len(tkl))
            stats.finalize()
        return outs, timing

    def _serve_multi_pooled(self, states: Sequence[PrefixState],
                            prefix_ids: Sequence[int],
                            suffix_token_lists: Sequence[List[int]]
                            ) -> Tuple[List[List[int]], dict]:
        """Split-cascade multi-prefix path (attention-only stacks)."""
        n = len(suffix_token_lists)
        t0 = time.perf_counter()
        # NP is a SHAPE (the pool's stacked batch dim), so bucket it to
        # powers of two like every other serving shape (DESIGN.md §3):
        # pad with repeats of state 0 — rows no prefix_idx points at,
        # so they only bound the number of compiled executables.
        np_true = len(states)
        states = list(states)
        states += [states[0]] * (_bucket_batch(np_true) - np_true)
        common = max(st.capacity for st in states)
        # the stacked pool is a device copy of every prefix KV, so
        # rebuilding it per micro-batch would cost O(sum prefix bytes)
        # even on 100% pool hits — memoize the last stack, keyed on the
        # states' process-unique uids (a re-prefilled or different state
        # set is a new PrefixState -> new uid -> rebuild).  The memo is
        # one stack deep: HBM held beyond any PrefixPool budget is
        # bounded by a single NP-bucketed stacked copy, and it holds no
        # references to the states themselves, so pool evictions free
        # their buffers immediately.
        stack_key = (tuple(st.uid for st in states), common)
        if self._pool_stack is not None and self._pool_stack[0] == stack_key:
            pool = self._pool_stack[1]
        else:
            pool = M.stack_prefix_caches(
                [M.pad_prefix_cache(st.cache, common) for st in states])
            self._pool_stack = (stack_key, pool)
        b = _bucket_batch(n)
        pads = [list(t) for t in suffix_token_lists] + \
               [[EOS]] * (b - n)                        # batch padding rows
        pid = list(prefix_ids) + [0] * (b - n)
        offs = np.asarray([states[p].prefix_len for p in pid], np.int32)
        embeds, positions, valid, lens = self._embed_padded(pads, None, offs)
        cache = M.init_suffix_cache(
            self.cfg, b, self._suffix_capacity_for(embeds.shape[1]))
        pidx = jnp.asarray(pid, jnp.int32)
        offj = jnp.asarray(offs)
        prefill = self._prefill_jit(b, embeds.shape[1])
        cache, logits, _ = prefill(self.params, embeds, positions, valid,
                                   cache, pool, offj, pidx)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(first)
        t_prefill = time.perf_counter() - t0

        t0 = time.perf_counter()
        lengths = jnp.asarray(offs + lens, jnp.int32)
        decode = self._decode_jit(b)
        out = decode(self.params, first, lengths, cache, pool, offj, pidx)
        out = np.asarray(jax.block_until_ready(out))
        t_decode = time.perf_counter() - t0
        toks = [self._cut(out[i]) for i in range(n)]
        return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                      "batch": b, "split_prefix": True,
                      "num_prefixes": np_true,
                      "prefill_share": [t_prefill / n] * n,
                      "decode_share": [t_decode / n] * n}

    def _serve_multi_grouped(self, states: Sequence[PrefixState],
                             prefix_ids: Sequence[int],
                             suffix_token_lists: Sequence[List[int]]
                             ) -> Tuple[List[List[int]], dict]:
        """Fallback: serve each cluster's members as their own
        ``generate_with_prefix`` sub-batch (stateful / cross-attention
        stacks, where the prefix is not a set of positional KV slots).
        Per-member shares come from each member's own sub-batch."""
        m = len(suffix_token_lists)
        outs = [None] * m
        agg = {"prefill_s": 0.0, "decode_s": 0.0, "batch": 0,
               "split_prefix": False, "num_prefixes": len(states),
               "prefill_share": [0.0] * m, "decode_share": [0.0] * m}
        for p in sorted(set(prefix_ids)):
            idxs = [i for i, q in enumerate(prefix_ids) if q == p]
            sub, t = self._serve_with_prefix(
                states[p], [suffix_token_lists[i] for i in idxs])
            for j, i in enumerate(idxs):
                outs[i] = sub[j]
                agg["prefill_share"][i] = t["prefill_share"][j]
                agg["decode_share"][i] = t["decode_share"][j]
            agg["prefill_s"] += t["prefill_s"]
            agg["decode_s"] += t["decode_s"]
            agg["batch"] = max(agg["batch"], t["batch"])
        return outs, agg

    def _serve_with_prefix(self, state: PrefixState,
                           suffix_token_lists: Sequence[List[int]]
                           ) -> Tuple[List[List[int]], dict]:
        if self._stateful:
            groups = {}
            for i, tkl in enumerate(suffix_token_lists):
                groups.setdefault(len(tkl), []).append(i)
            if len(groups) > 1:
                m = len(suffix_token_lists)
                outs = [None] * m
                agg = {"prefill_s": 0.0, "decode_s": 0.0, "batch": 0,
                       "split_prefix": False,
                       "prefill_share": [0.0] * m,
                       "decode_share": [0.0] * m}
                for length, idxs in sorted(groups.items()):
                    sub, t = self._serve_with_prefix(
                        state, [suffix_token_lists[i] for i in idxs])
                    # per-member attribution: each member pays its OWN
                    # sub-batch's share — dividing the summed time by m
                    # would bill short-suffix members for long ones
                    for j, i in enumerate(idxs):
                        outs[i] = sub[j]
                        agg["prefill_share"][i] = t["prefill_share"][j]
                        agg["decode_share"][i] = t["decode_share"][j]
                    agg["prefill_s"] += t["prefill_s"]
                    agg["decode_s"] += t["decode_s"]
                    agg["batch"] = max(agg["batch"], t["batch"])
                return outs, agg
        n = len(suffix_token_lists)
        b = _bucket_batch(n)
        pads = [list(t) for t in suffix_token_lists] + \
               [[EOS]] * (b - n)                        # batch padding rows
        use_split = self.use_split_prefix and state.enc_len == 0
        t0 = time.perf_counter()
        pad_to = len(suffix_token_lists[0]) if self._stateful else None
        if self._stateful:
            pads = [list(t)[:pad_to] + [EOS] * (pad_to - len(t))
                    if len(t) < pad_to else list(t) for t in pads]
        embeds, positions, valid, lens = self._embed_padded(
            pads, None, state.prefix_len, pad_to=pad_to)
        if use_split:
            # Split cascade: B members cost prefix_capacity + B×suffix
            # slots of HBM; the prefix KV is attended in place.
            cache = M.init_suffix_cache(
                self.cfg, b, self._suffix_capacity_for(embeds.shape[1]))
            prefix, offset = state.cache, jnp.int32(state.prefix_len)
        else:
            template = jax.eval_shape(
                lambda: M.init_cache(self.cfg, b, state.capacity,
                                     enc_len=state.enc_len))
            cache = state.broadcast(template)
            prefix, offset = None, 0
        prefill = self._prefill_jit(b, embeds.shape[1])
        cache, logits, _ = prefill(self.params, embeds, positions, valid,
                                   cache, prefix, offset, None)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(first)
        t_prefill = time.perf_counter() - t0

        t0 = time.perf_counter()
        lengths = jnp.asarray(state.prefix_len + lens, jnp.int32)
        decode = self._decode_jit(b)
        out = decode(self.params, first, lengths, cache, prefix, offset, None)
        out = np.asarray(jax.block_until_ready(out))
        t_decode = time.perf_counter() - t0
        toks = [self._cut(out[i]) for i in range(n)]
        return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                      "batch": b, "split_prefix": use_split,
                      "prefill_share": [t_prefill / n] * n,
                      "decode_share": [t_decode / n] * n}

    # ------------------------------------------------------------------
    # baseline path
    # ------------------------------------------------------------------
    def generate(self, prompt_tokens: List[int],
                 soft: Optional[np.ndarray] = None
                 ) -> Tuple[List[int], dict]:
        """Vanilla single-query generation (the paper's baseline)."""
        t0 = time.perf_counter()
        embeds, positions, valid, lens = self._embed_padded(
            [prompt_tokens], soft, 0,
            pad_to=None if not self._stateful else
            len(prompt_tokens) + (0 if soft is None else soft.shape[0]))
        cache = M.init_cache(self.cfg, 1, self._capacity_for(int(lens[0]), suffix_headroom=0))
        prefill = self._prefill_jit(1, embeds.shape[1])
        cache, logits, _ = prefill(self.params, embeds, positions, valid,
                                   cache, None, 0, None)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(first)
        t_prefill = time.perf_counter() - t0

        t0 = time.perf_counter()
        decode = self._decode_jit(1)
        out = decode(self.params, first, jnp.asarray(lens, jnp.int32), cache,
                     None, 0, None)
        out = np.asarray(jax.block_until_ready(out))
        t_decode = time.perf_counter() - t0
        return self._cut(out[0]), {"prefill_s": t_prefill,
                                   "decode_s": t_decode}

    def _cut(self, ids: np.ndarray) -> List[int]:
        out = []
        for t in ids.tolist():
            if t == EOS:
                break
            out.append(int(t))
        return out

    def warmup(self, suffix_len: int = 32, batches: Sequence[int] = (1,)):
        """Pre-compile the common shape buckets (excluded from timings).
        Warmup traffic is not real serving: keep it out of CacheStats."""
        for b in batches:
            dummy = [[EOS] * suffix_len for _ in range(b)]
            if b == 1:
                self.generate(dummy[0])
            else:
                st, _ = self.prefill_prefix([EOS] * suffix_len,
                                            _record=False)
                self.generate_with_prefix(st, dummy, _record=False)

    def warmup_pooled(self, prefix_len: int, suffix_len: int = 32,
                      batches: Sequence[int] = (1, 2, 4),
                      num_prefixes: Sequence[int] = (1, 2, 4)):
        """Pre-compile the multi-prefix (batch, NP) bucket grid for
        pooled online serving: micro-batch composition depends on
        arrival dynamics, so an online trace can touch any combination
        of member-batch and pool-size buckets at any moment — compile
        them up front so no trace lands in a timed region.
        ``prefix_len`` should match the expected representative length
        (it selects the prefix-capacity bucket).  Not recorded."""
        states = []
        for _ in range(max(num_prefixes)):
            st, _ = self.prefill_prefix([EOS] * prefix_len, _record=False)
            states.append(st)
        for np_ in num_prefixes:
            for b in batches:
                dummy = [[EOS] * suffix_len for _ in range(b)]
                pids = [i % np_ for i in range(b)]
                self.generate_multi_prefix(states[:np_], pids, dummy,
                                           _record=False)
