"""Continuous in-flight batching over the paged serving engine
(DESIGN.md §9).

The drain-serve loop (``OnlineScheduler.serve_batch`` driven by
``GraphRAGPipeline.serve_stream``) serves each micro-batch to FULL
completion: every row burns all ``max_new_tokens - 1`` scan steps even
after emitting EOS, and a request arriving one tick after a batch
starts waits out the whole batch's decode — head-of-line blocking the
refcounted block arena was built to make unnecessary.  This module
replaces the monolithic decode with a persistent in-flight batch:

* **Chunked decode** — ``engine.decode_step`` runs decode in fixed
  ``chunk``-step scans; between chunks the host owns the batch again.
  Chunking a scan preserves carry semantics exactly, so the emitted
  token stream is identical to the monolithic decode (the drain-serve
  path is kept as the A/B oracle and the exactness test).
* **Mid-flight retirement** — a row that emits EOS (or exhausts its
  budget) retires at the next chunk boundary: its main-arena suffix
  reservation is freed immediately (``pool.decref``), its prefix block
  pins drop, and its EXACT prefill/decode attribution is recorded —
  not a uniform ``t / n`` share.
* **Admission between chunks** — newly drained arrivals prefill into
  free slots against their cluster's (pinned) prefix pages while
  survivors keep decoding out of the same arena; nothing waits for the
  batch to drain.

Device layout: each slot owns a fixed band of rows in a compact
suffix **sub-arena** (``KVBlockPool.sub_arena``) — the decode carry is
``slots × blocks_per_slot`` rows, while the main arena rides along
READ-ONLY as the prefix source (the same split the drain path's
``extract`` optimization uses, made persistent).  Admission prefills
the newcomer's suffix KV directly into its slot's rows (main arena as
the read-only ``prefix`` operand); per-row suffix blocks in the MAIN
arena are reserved for the row's lifetime so arena pressure, pool
eviction, and admission stay one refcount mechanism.  Slot reuse is a
position reset on the retiring tenant's rows (``reset_pos_rows``) —
the sub-arena is never reallocated, so slot turnover causes no arena
churn.

``InflightBatch`` owns the slots and device state; ``ContinuousEngine``
is the serving facade (admission, retirement, CacheStats accounting).
``OnlineScheduler.serve_continuous`` feeds it assigned, pool-pinned
requests; ``GraphRAGPipeline.serve_stream`` is the event loop on top.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import PrefixState
from repro.core.paged import NULL_BLOCK, reset_pos_rows
from repro.data.tokenizer import EOS, PAD
from repro.models import model as M
from repro.serving.bucketing import blocks_for, bucket_len, bucket_pow2
from repro.serving.engine import Request


@dataclasses.dataclass
class RowState:
    """Host bookkeeping for one in-flight slot."""
    payload: Any                    # caller's handle, returned at retirement
    state: Optional[PrefixState]    # prefix served against (blocks pinned)
    prefix_blocks: List[int]        # SNAPSHOT of the pinned chain blocks
                                    # (a mid-flight pool eviction drops the
                                    # state's own handles, never this list)
    blocks: List[int]               # main-arena suffix reservation
    suffix_len: int                 # suffix tokens actually consumed
    offset: int                     # prefix length (suffix scatter base)
    pos: int                        # next decode position
    tok: int                        # next decode input token
    emitted: List[int]              # first token + decode stream (raw)
    steps_left: int                 # decode budget remaining
    admitted_s: float               # caller clock at admission
    prefill_s: float                # this row's share of its admission
    on_retire: Optional[Callable[[Any], None]]
    decode_s: float = 0.0           # exact: sum of chunk_time / live_rows
    steps: int = 0                  # decode steps actually consumed
    plen: int = 0                   # context length for stats (chain:
                                    # prefix_len; composed: total_len)
    pinned: List[int] = dataclasses.field(default_factory=list)
                                    # blocks this row increfed at
                                    # admission (decrefed at retirement)
    prefix_offsets: List[int] = dataclasses.field(default_factory=list)
                                    # per-prefix-block position deltas
    prefix_skips: List[int] = dataclasses.field(default_factory=list)
                                    # per-prefix-block leading-slot masks
                                    # (composed rows, DESIGN.md §14)


@dataclasses.dataclass
class RowResult:
    """One retired row (tokens are EOS-cut, ready for detokenization)."""
    payload: Any
    tokens: List[int]
    prefill_s: float
    decode_s: float
    decode_steps: int
    admitted_s: float


class InflightBatch:
    """Fixed-slot device state of the continuous batch (see module
    docstring).  ``max_slots`` is bucketed to a power of two and is the
    compiled decode batch; ``max_suffix_len`` fixes the per-slot suffix
    capacity (suffix + decode tail), hence the sub-arena size
    ``slots × blocks_per_slot + 1`` (the +1 is a trash row that
    admission's batch-padding rows write into)."""

    def __init__(self, engine, max_slots: int, chunk: int,
                 max_suffix_len: int) -> None:
        assert engine.use_paged, \
            "continuous batching rides the paged backend (DESIGN.md §9)"
        assert chunk >= 1, chunk
        self.engine = engine
        self.chunk = int(chunk)
        # compiled decode batch is a power-of-two bucket, but the
        # caller's concurrency cap is honored exactly: only the first
        # ``usable`` slots ever admit (the rest are permanent done-padding)
        self.usable = max(1, int(max_slots))
        self.num_slots = bucket_pow2(self.usable)
        self.t_max = bucket_len(max_suffix_len, engine.bucket)
        suffix_cap = engine._suffix_capacity_for(self.t_max)
        self.nbs = blocks_for(suffix_cap, engine.block_size)
        self.slots: List[Optional[RowState]] = [None] * self.num_slots
        # persistent decode carry: slot i owns sub rows
        # [i*nbs, (i+1)*nbs); row num_slots*nbs is the trash row
        self.sub = engine.block_pool.sub_arena(self.num_slots * self.nbs + 1)
        self.trash_row = self.num_slots * self.nbs
        self._sub_pages = np.arange(
            self.num_slots * self.nbs,
            dtype=np.int32).reshape(self.num_slots, self.nbs)

    # ------------------------------------------------------------------
    @property
    def live(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def free(self) -> List[int]:
        return [i for i in range(self.usable) if self.slots[i] is None]

    def slot_rows(self, slot: int) -> np.ndarray:
        return self._sub_pages[slot]

    # ------------------------------------------------------------------
    def _with_sub(self, fn):
        """Run a jitted call that consumes the (donated) sub-arena and
        returns the updated sub as its LAST output; re-home it even when
        the call raises (mirrors ``ServingEngine._with_arena``)."""
        sub_in, self.sub = self.sub, None
        try:
            out = fn(sub_in)
        except BaseException:
            self.sub = sub_in
            raise
        self.sub = out[-1]
        return out

    def reset_slots(self, slots: Sequence[int]) -> None:
        """Mark the slots' sub rows empty (pos = -1) before a new tenant
        prefills into them — stale positions from the previous tenant
        would otherwise be attended as live KV.  The row list is padded
        to the power-of-two admission bucket (duplicate indices are
        harmless for a set-to-(-1) scatter) so the jitted reset
        compiles per BUCKET, not per exact admission count — a k=3
        admission must not land an XLA compile inside a timed TTFT."""
        rows = np.concatenate([self.slot_rows(s) for s in slots])
        kb = bucket_pow2(len(slots))
        if kb > len(slots):
            rows = np.concatenate(
                [rows, np.tile(rows[:self.nbs], kb - len(slots))])
        self._with_sub(lambda sub: (reset_pos_rows(sub, rows),))

    def nbp_for(self, states: Sequence[Optional[PrefixState]]) -> int:
        """Power-of-two prefix page-table width covering ``states``
        (a chain state's row concatenates its whole root→leaf path)."""
        return bucket_pow2(max(
            [1] + [len(st.chain_blocks()) for st in states
                   if st is not None]))


class ContinuousEngine:
    """Continuous-serving facade over a paged ``ServingEngine``.

    ``admit(requests, ...)`` prefills newcomers into free slots (one
    batched suffix prefill against their pinned prefix pages);
    ``step()`` advances every live row by one ``chunk``-step decode;
    retirements land in ``pop_retired()``.  ``max_suffix_len`` bounds
    the suffix tokens a request may carry (capacity is a compiled
    shape); requests beyond ``free_slots`` are the caller's to queue —
    admission control IS the scheduler's drain loop.
    """

    def __init__(self, engine, *, max_slots: int = 8, chunk: int = 4,
                 max_suffix_len: int = 64) -> None:
        self.engine = engine
        self.batch = InflightBatch(engine, max_slots, chunk, max_suffix_len)
        self._retired: List[RowResult] = []

    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self.batch.free)

    @property
    def in_flight(self) -> int:
        return len(self.batch.live)

    def pop_retired(self) -> List[RowResult]:
        out, self._retired = self._retired, []
        return out

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def admit(self, requests: Sequence[Request], payloads=None,
              now: float = 0.0,
              on_retire: Optional[Callable[[Any], None]] = None) -> float:
        """Prefill ``requests`` into free slots; returns the admission's
        prefill wall seconds (each row is billed ``t / k``).

        Prefix block references are taken PER ROW for the row's
        lifetime, so a pool eviction mid-flight can never recycle the
        pages a survivor is still walking; per-row suffix blocks are
        reserved in the main arena (this is what couples admission to
        arena pressure — the allocation may reclaim cold POOLED
        prefixes, but pinned in-flight ones survive).  Rows whose first
        token is already EOS (or whose budget is one token) retire
        immediately without entering decode.
        """
        eng, b = self.engine, self.batch
        pool = eng.block_pool
        k = len(requests)
        assert 0 < k <= len(b.free), (k, len(b.free))
        if payloads is None:
            payloads = [None] * k
        assert len(payloads) == k
        if any(r.composition is not None for r in requests):
            return self._admit_composed(requests, payloads, now, on_retire)
        states = [r.prefix for r in requests]
        for st in states:
            if st is not None:
                assert st.is_paged and st.block_pool is pool, \
                    "continuous admission needs page-table states " \
                    "from this engine"
        for r in requests:
            assert len(r.suffix_tokens) <= b.t_max, \
                (len(r.suffix_tokens), b.t_max)
        slots = b.free[:k]

        t0 = time.perf_counter()
        kb = bucket_pow2(k)
        suffixes = [list(r.suffix_tokens) for r in requests] \
            + [[EOS]] * (kb - k)                     # batch padding rows
        offs = np.asarray([st.prefix_len if st else 0 for st in states]
                          + [0] * (kb - k), np.int32)
        # snapshot each row's full chain walk (ancestors ++ own segment,
        # DESIGN.md §10): the pins below and the decode page rows use
        # this list, so a pool eviction mid-flight (which drops the
        # STATE's handles) can never strand a live row
        prefix_blocks = [st.chain_blocks() if st is not None else []
                         for st in states]
        pinned = 0
        flat: Optional[List[int]] = None
        try:
            for blocks in prefix_blocks:
                if blocks:
                    pool.incref(blocks)              # per-row, per-lifetime
                pinned += 1
            # per-row main-arena suffix reservation; may reclaim cold
            # pooled prefixes (never pinned in-flight ones).  Plain
            # alloc, no pos reset: these blocks are budget, the KV
            # lives in the sub-arena (any later tenant resets/overwrites)
            flat = pool.alloc(k * b.nbs, suffix=True)
            for j in range(k):
                pool.note_tokens(flat[j * b.nbs:(j + 1) * b.nbs],
                                 len(requests[j].suffix_tokens),
                                 suffix=True)
            eng.cache_mgr.stats.record_blocks(pool)

            nbp = b.nbp_for(states)
            prow = np.full((kb, nbp), NULL_BLOCK, np.int32)
            for j, blocks in enumerate(prefix_blocks):
                prow[j, :len(blocks)] = blocks
            srow = np.full((kb, b.nbs), b.trash_row, np.int32)
            for j, s in enumerate(slots):
                srow[j] = b.slot_rows(s)
            b.reset_slots(slots)
            embeds, positions, valid, lens = eng._embed_padded(
                suffixes, None, offs, pad_to=b.t_max)
            prefill = eng._prefill_jit(kb, embeds.shape[1])
            logits = self._prefill_into_sub(prefill, embeds, positions,
                                            valid, offs, prow, srow)
            first = np.asarray(jax.block_until_ready(
                jnp.argmax(logits, axis=-1).astype(jnp.int32)))
            t_prefill = time.perf_counter() - t0
        except BaseException:
            # unwind: no phantom prefix refs, no leaked reservations
            for blocks in prefix_blocks[:pinned]:
                if blocks:
                    pool.decref(blocks)
            if flat is not None:
                pool.decref(flat, suffix=True)
            raise

        for j, (slot, req, st) in enumerate(zip(slots, requests, states)):
            row = RowState(
                payload=payloads[j], state=st,
                prefix_blocks=prefix_blocks[j],
                blocks=flat[j * b.nbs:(j + 1) * b.nbs],
                suffix_len=len(req.suffix_tokens), offset=int(offs[j]),
                pos=int(offs[j]) + int(lens[j]), tok=int(first[j]),
                emitted=[int(first[j])],
                steps_left=eng.max_new_tokens - 1,
                admitted_s=now, prefill_s=t_prefill / k,
                on_retire=on_retire,
                plen=st.prefix_len if st is not None else 0,
                pinned=prefix_blocks[j])
            b.slots[slot] = row
            if row.tok == EOS or row.steps_left == 0:
                self._retire(slot)       # no decode owed: retire now
        return t_prefill

    def _admit_composed(self, requests: Sequence[Request], payloads,
                        now: float,
                        on_retire: Optional[Callable[[Any], None]]
                        ) -> float:
        """Admission for batches carrying composition plans (DESIGN.md
        §14) — chain and prefixless rows ride along as degenerate plans
        (``ServingEngine._row_plan``).  Differs from the plain path in
        the same three ways the drain ``_serve_composed`` does: prefix
        tables carry per-block offsets/skips, the prefill computes a
        NON-CONTIGUOUS fresh stream at explicit absolute positions, and
        the slot's fixed suffix band anchors at the row's first fresh
        position.  The row's whole fresh SPAN (first fresh position to
        prompt end — cached holes included) must fit ``max_suffix_len``:
        the band is a compiled shape, so this is an admission contract,
        not a serving-time reallocation."""
        eng, b = self.engine, self.batch
        pool = eng.block_pool
        k = len(requests)
        slots = b.free[:k]
        t0 = time.perf_counter()
        kb = bucket_pow2(k)
        plans: List[dict] = []
        flat: Optional[List[int]] = None
        try:
            for r in requests:
                plans.append(eng._row_plan(r))     # pins plan["pinned"]
            for p in plans:
                assert len(p["ids"]) <= b.t_max, \
                    (len(p["ids"]), b.t_max)
                assert p["prompt_len"] - p["slot_off"] <= b.t_max, \
                    ("composed fresh span exceeds the slot band",
                     p["prompt_len"], p["slot_off"], b.t_max)
            pad = dict(blocks=[], offsets=[], skips=[], pinned=[],
                       ids=[EOS], pos=[0], slot_off=0, prompt_len=1)
            plans_kb = plans + [pad] * (kb - k)     # batch padding rows
            flat = pool.alloc(k * b.nbs, suffix=True)
            for j in range(k):
                pool.note_tokens(flat[j * b.nbs:(j + 1) * b.nbs],
                                 len(plans[j]["ids"]), suffix=True)
            eng.cache_mgr.stats.record_blocks(pool)

            nbp = bucket_pow2(max(1, max(len(p["blocks"])
                                         for p in plans_kb)))
            prow = np.full((kb, nbp), NULL_BLOCK, np.int32)
            poff = np.zeros((kb, nbp), np.int32)
            pskip = np.zeros((kb, nbp), np.int32)
            for j, p in enumerate(plans_kb):
                w = len(p["blocks"])
                prow[j, :w] = p["blocks"]
                poff[j, :w] = p["offsets"]
                pskip[j, :w] = p["skips"]
            srow = np.full((kb, b.nbs), b.trash_row, np.int32)
            for j, s in enumerate(slots):
                srow[j] = b.slot_rows(s)
            b.reset_slots(slots)
            ids = np.full((kb, b.t_max), PAD, np.int32)
            pos = np.zeros((kb, b.t_max), np.int32)
            valid = np.zeros((kb, b.t_max), bool)
            for j, p in enumerate(plans_kb):
                w = len(p["ids"])
                ids[j, :w] = p["ids"]
                pos[j, :w] = p["pos"]
                valid[j, :w] = True
            embeds = M.embed_tokens(eng.params, jnp.asarray(ids))
            offs = np.asarray([p["slot_off"] for p in plans_kb], np.int32)
            prefill = eng._prefill_jit(kb, b.t_max)
            out = b._with_sub(lambda sub: _cache_last(prefill(
                eng.params, embeds, jnp.asarray(pos), jnp.asarray(valid),
                sub, pool.prefix_source(), jnp.asarray(offs),
                jnp.asarray(prow), jnp.asarray(srow), jnp.asarray(poff),
                jnp.asarray(pskip))))
            first = np.asarray(jax.block_until_ready(
                jnp.argmax(out[0], axis=-1).astype(jnp.int32)))
            t_prefill = time.perf_counter() - t0
        except BaseException:
            # unwind: no phantom segment pins, no leaked reservations
            for p in plans:
                if p["pinned"]:
                    pool.decref(p["pinned"])
            if flat is not None:
                pool.decref(flat, suffix=True)
            raise

        # gap-span capture (DESIGN.md §15): the fresh KV sits in the
        # slots' sub-arena bands, which persist for the rows' lifetime —
        # but capture NOW, before decode overwrites nothing (gaps are
        # pre-prompt) and so repeat arrivals in the very next drain tick
        # already hit
        if eng.gap_admit is not None:
            eng._capture_gaps(requests, plans,
                              [b.slot_rows(s) for s in slots], src=b.sub)

        for j, (slot, req, p) in enumerate(zip(slots, requests, plans)):
            if req.composition is not None:
                eng.cache_mgr.stats.record_compose(req.composition)
            row = RowState(
                payload=payloads[j], state=req.prefix,
                prefix_blocks=list(p["blocks"]),
                blocks=flat[j * b.nbs:(j + 1) * b.nbs],
                suffix_len=len(req.suffix_tokens),
                offset=int(p["slot_off"]), pos=int(p["prompt_len"]),
                tok=int(first[j]), emitted=[int(first[j])],
                steps_left=eng.max_new_tokens - 1,
                admitted_s=now, prefill_s=t_prefill / k,
                on_retire=on_retire,
                plen=int(p["prompt_len"]) - len(req.suffix_tokens),
                pinned=p["pinned"], prefix_offsets=list(p["offsets"]),
                prefix_skips=list(p["skips"]))
            b.slots[slot] = row
            if row.tok == EOS or row.steps_left == 0:
                self._retire(slot)       # no decode owed: retire now
        return t_prefill

    def _prefill_into_sub(self, prefill, embeds, positions, valid,
                          offs, prow, srow):
        """Suffix prefill with the sub-arena as the (donated) cache and
        the prefix source — the main arena, or the int8 quantized arena
        under ``quantize_prefix`` — read-only: the admission
        counterpart of the chunked decode's carry split.  Returns the
        last-token logits."""
        eng, b = self.engine, self.batch
        out = b._with_sub(lambda sub: _cache_last(prefill(
            eng.params, embeds, positions, valid, sub,
            eng.block_pool.prefix_source(),
            jnp.asarray(offs), jnp.asarray(prow), jnp.asarray(srow))))
        return out[0]

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def step(self) -> float:
        """Advance every live row by one ``chunk``-step decode; retire
        rows that emit EOS or exhaust their budget.  Returns the chunk's
        wall seconds (0.0 with nothing live).  Each live row accrues
        EXACTLY ``wall / live`` decode seconds for this chunk — rows
        that already retired accrue nothing."""
        eng, b = self.engine, self.batch
        live = b.live
        if not live:
            return 0.0
        n = b.num_slots
        tok = np.full(n, EOS, np.int32)
        pos = np.zeros(n, np.int32)
        done = np.ones(n, bool)
        offs = np.zeros(n, np.int32)
        # page rows come from each row's admission-time SNAPSHOT of its
        # chain walk — valid even if the pooled state was evicted
        # mid-flight (the row's own pins keep the blocks alive)
        nbp = bucket_pow2(max(
            [1] + [len(b.slots[i].prefix_blocks) for i in live]))
        prow = np.full((n, nbp), NULL_BLOCK, np.int32)
        for i in live:
            r = b.slots[i]
            tok[i], pos[i], done[i], offs[i] = r.tok, r.pos, False, r.offset
            prow[i, :len(r.prefix_blocks)] = r.prefix_blocks
        # composed rows decode with per-block offset/skip tables; pure
        # chain batches pass None and keep their pre-composition
        # executable (None vs array is a separate trace)
        poff = pskip = None
        if any(any(b.slots[i].prefix_offsets) or any(b.slots[i].prefix_skips)
               for i in live):
            poff = np.zeros((n, nbp), np.int32)
            pskip = np.zeros((n, nbp), np.int32)
            for i in live:
                r = b.slots[i]
                w = len(r.prefix_offsets)
                poff[i, :w] = r.prefix_offsets
                pskip[i, :w] = r.prefix_skips

        t0 = time.perf_counter()
        toks = b._with_sub(lambda sub: eng.decode_step(
            tok, pos, done, sub, offs, prow, b._sub_pages,
            steps=b.chunk, prefix_offsets=poff, prefix_skips=pskip))[0]
        out = np.asarray(jax.block_until_ready(toks))
        wall = time.perf_counter() - t0

        share = wall / len(live)
        for i in live:
            r = b.slots[i]
            r.decode_s += share
            finished = False
            for t in out[i].tolist():
                r.emitted.append(int(t))
                r.steps += 1
                r.steps_left -= 1
                if t == EOS or r.steps_left == 0:
                    finished = True
                    break
            if finished:
                self._retire(i)
            else:
                r.tok = int(out[i, -1])
                r.pos += b.chunk
                # keep the fragmentation gauge honest mid-flight: the
                # reservation now also stores this row's decode tokens
                pool = eng.block_pool
                pool.note_tokens(r.blocks, r.suffix_len + r.steps,
                                 suffix=True)
        return wall

    def flush(self, max_chunks: int = 10_000) -> None:
        """Decode until every in-flight row retires (tests/teardown)."""
        for _ in range(max_chunks):
            if not self.in_flight:
                return
            self.step()
        raise RuntimeError("flush did not drain the in-flight batch")

    # ------------------------------------------------------------------
    # warmup (pre-compile shape buckets; excluded from timings/stats)
    # ------------------------------------------------------------------
    def warmup(self, prefix_lens: Sequence[int],
               suffix_len: int = 8) -> None:
        """Pre-compile the continuous shape grid: for one
        representative prefix per page-width bucket in ``prefix_lens``
        and every admission batch bucket ``kb ∈ {1, 2, ..., slots}``,
        run one admit + chunk + flush.  Online admission composition
        depends on arrival dynamics, so any (batch, width) combination
        can appear at any moment — compile them up front or an XLA
        compile lands inside a reported TTFT (EXPERIMENTS.md
        protocol).  Warmup traffic is not real serving: CacheStats are
        shielded and the throwaway prefix states are released."""
        from repro.core.cache import CacheStats
        eng, b = self.engine, self.batch
        assert self.in_flight == 0, "warm up an idle engine"
        seen, keep = set(), []
        for p in sorted(int(p) for p in prefix_lens):
            w = bucket_pow2(blocks_for(p, eng.block_size))
            if w not in seen:
                seen.add(w)
                keep.append(p)
        saved = eng.cache_mgr.stats
        eng.cache_mgr.stats = CacheStats()
        try:
            for plen in keep:
                st, _ = eng.prefill_prefix([EOS] * plen, _record=False)
                try:
                    # every admission-batch BUCKET a live drain can hit:
                    # k <= usable rows bucket to bucket_pow2(k), which
                    # for non-power-of-two usable exceeds usable itself
                    for kb in sorted({bucket_pow2(k)
                                      for k in range(1, b.usable + 1)}):
                        sfx = [EOS] * min(suffix_len, b.t_max)
                        self.admit([Request(list(sfx), st)
                                    for _ in range(min(kb, b.usable))])
                        self.flush()
                        self.pop_retired()
                    # the warm rows may all have retired AT ADMISSION
                    # (instant EOS / one-token budget), in which case
                    # flush() never ran a chunk — force one all-done
                    # decode_step so this width's chunked-decode
                    # executable is traced regardless
                    n = b.num_slots
                    nbp = b.nbp_for([st])
                    prow = np.full((n, nbp), NULL_BLOCK, np.int32)
                    prow[0] = st.page_row(nbp)
                    b._with_sub(lambda sub: eng.decode_step(
                        np.full(n, EOS, np.int32), np.zeros(n, np.int32),
                        np.ones(n, bool), sub, np.zeros(n, np.int32),
                        prow, b._sub_pages, steps=b.chunk))
                finally:
                    st.release()
        finally:
            eng.cache_mgr.stats = saved

    # ------------------------------------------------------------------
    # retirement
    # ------------------------------------------------------------------
    def _retire(self, slot: int) -> None:
        """Free the row's arena footprint THE MOMENT it is done:
        suffix reservation back to the free list, prefix pins dropped
        (an evicted-but-in-flight prefix may free here), exact per-row
        accounting recorded."""
        eng, b = self.engine, self.batch
        pool = eng.block_pool
        r = b.slots[slot]
        b.slots[slot] = None
        # freeing IS the token-count reconciliation: decref zeroes the
        # freed blocks' stored-token counters, so the gauge never keeps
        # charging a retired row's unconsumed decode budget
        pool.decref(r.blocks, suffix=True)
        if r.pinned:
            pool.decref(r.pinned)    # the admission-time chain/segment pins
        stats = eng.cache_mgr.stats
        stats.record_served(1)
        stats.record_member(r.plen + r.suffix_len, r.suffix_len)
        stats.finalize()
        stats.record_blocks(pool)
        toks = eng._cut(np.asarray(r.emitted, np.int32))
        if r.on_retire is not None:
            r.on_retire(r.payload)
        self._retired.append(RowResult(
            payload=r.payload, tokens=toks, prefill_s=r.prefill_s,
            decode_s=r.decode_s, decode_steps=r.steps,
            admitted_s=r.admitted_s))


def _cache_last(out):
    """(cache, logits, lengths) -> (logits, lengths, cache): put the
    donated sub-arena LAST for ``InflightBatch._with_sub``."""
    cache, logits, lengths = out
    return logits, lengths, cache
