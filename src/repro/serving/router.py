"""Cluster-affinity router over N serving replicas (DESIGN.md §13).

The single-engine serving stack caps throughput at one device's decode
rate no matter how many queries share a prefix.  ``ReplicaRouter`` is
the data-parallel half of the replica subsystem (the tensor-parallel
half is ``distributed/kv_sharding.py``): N ``ServingEngine`` replicas,
each owning a PRIVATE ``KVBlockPool`` arena, ``PrefixPool``, host tier,
and ``CacheStats`` window, behind one router that decides — per query,
at arrival time — which replica serves it.

Three policies, in priority order:

* **cluster affinity** — a cluster's prefix chain is materialized on
  exactly ONE replica; members route there.  Prefix reuse is the whole
  SubGCache win, and a cluster spread over two replicas would prefill
  its representative twice and halve both hit rates.
* **least-loaded spawn** — a NEW cluster is placed on the replica with
  the smallest backlog (routed − retired; ties round-robin), so cold
  clusters spread instead of piling onto replica 0.
* **rebalance by migration** — when one replica runs hot
  (``load_max > hot_ratio × load_mean`` with a gap ≥ ``min_gap``), the
  router moves a co-located cluster from the hot replica to the
  coldest one — a DRAINED one (no pending backlog: migration redirects
  future arrivals only, so a backlogged cluster would leave its
  queries behind while taking its resident prefix with it).  The
  move is the existing HOST ROUND-TRIP — targeted
  ``PrefixPool.demote_to_host`` on the source, a ``HostSegment``
  handoff between the two host tiers, lazy ``promote`` on the
  destination at the cluster's next hit — never a device-to-device
  copy path to maintain.  Migration affects FUTURE traffic only;
  queries already routed keep their replica.

ONE ``OnlineClusterAssigner`` is shared by every replica and consulted
by the router in GLOBAL arrival order.  That is the token-identity
argument: cluster evolution (centroid drift, spawns) is byte-identical
to a single-replica run of the same trace, and greedy generation
depends only on (prefix chain tokens, suffix tokens, params) — so each
query's token stream matches the single-replica drain oracle
regardless of replica count or placement.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.scheduler import (ArrivalQueue, Assignment,
                                     OnlineClusterAssigner,
                                     OnlineScheduler)


@dataclasses.dataclass
class Replica:
    """One serving replica: a private engine/pool/tier stack plus the
    router-side load account.  ``load`` (routed − retired) is the
    backlog the placement policies balance."""
    idx: int
    engine: Any                      # ServingEngine (cloned substrate)
    scheduler: OnlineScheduler       # shared assigner, PRIVATE pool
    queue: ArrivalQueue = dataclasses.field(default_factory=ArrivalQueue)
    clock: float = 0.0               # this replica's virtual time
    routed: int = 0                  # queries ever routed here
    retired: int = 0                 # queries finished here
    affinity_hits: int = 0           # routed to an existing placement
    spawns: int = 0                  # clusters first placed here

    @property
    def load(self) -> int:
        return self.routed - self.retired

    @property
    def stats(self):
        return self.engine.cache_mgr.stats


@dataclasses.dataclass
class Route:
    """One routing decision: the (globally ordered) assignment plus the
    replica that will serve the query."""
    replica: int
    assignment: Assignment


class SharedSegmentIndex:
    """Cross-replica content-addressed segment directory (DESIGN.md
    §15).  Each replica's scheduler publishes ``content tuple -> (that
    scheduler, its pool key)`` as segments are prefilled or captured;
    a ``try_compose`` registry miss on one replica can then FETCH the
    segment from wherever it lives, over the SAME host round-trip the
    router's migration uses (targeted demote on the source, a
    ``HostSegment`` handoff between host tiers, lazy promote on the
    destination) — never a device-to-device path.  A fetch that cannot
    land (pinned source, full tier, stale linkage) degrades to an
    ordinary miss; correctness never depends on the move."""

    def __init__(self) -> None:
        # content tuple -> list of (scheduler, pool_key) publications
        self._where: Dict[tuple, list] = {}
        self.fetches = 0          # segments moved cross-replica
        self.fetch_failures = 0   # foreign candidates that refused

    def __len__(self) -> int:
        return len(self._where)

    def publish(self, content: tuple, scheduler, pool_key) -> None:
        entries = self._where.setdefault(content, [])
        for i, (s, _) in enumerate(entries):
            if s is scheduler:
                entries[i] = (scheduler, pool_key)
                return
        entries.append((scheduler, pool_key))

    def retract(self, content: tuple, scheduler) -> None:
        entries = self._where.get(content)
        if not entries:
            return
        entries[:] = [(s, k) for s, k in entries if s is not scheduler]
        if not entries:
            del self._where[content]

    def fetch(self, content: tuple, dst) -> Optional[Hashable]:
        """Move ``content``'s segment from some OTHER replica into
        ``dst``'s host tier; returns the pool key it now lives under
        (``dst``'s registry learns the mapping, promotion onboards it
        on the caller's next lookup) or None."""
        tried = False
        for src, key in list(self._where.get(content, ())):
            if src is dst or dst.pool.tier is None:
                continue
            tried = True
            hseg = self._extract(src, key)
            if hseg is None:
                continue
            if not dst.pool.tier.admit(hseg):
                # nowhere to land: hand the bits back to the source
                # tier so the segment is not lost to a full admit
                if src.pool.tier is not None:
                    src.pool.tier.admit(hseg)
                continue
            # the source no longer holds the segment under that key —
            # its registry (and our publication for it) must forget it
            src._invalidate_key(key)
            dst._register_segment(content, key)
            self.fetches += 1
            return key
        if tried:
            self.fetch_failures += 1
        return None

    @staticmethod
    def _extract(src, key):
        """Pull one segment out of ``src`` as a ``HostSegment``: straight
        from its host tier when already demoted, else a targeted
        ``demote_to_host`` (refuses when pinned or anchoring resident
        descendants — the same rules migration obeys)."""
        pool = src.pool
        if pool.tier is None:
            return None
        if pool.tier.peek(key) is None and not pool.demote_to_host(key):
            return None
        return pool.tier.pop(key)


class ReplicaRouter:
    """Cluster-affinity front-end over ``replicas`` serving stacks.

    Build with :meth:`build` (clones the engine, wires per-replica
    pools/tiers/stats).  The serving loop calls :meth:`route` once per
    arrival IN ARRIVAL ORDER, :meth:`retire` when a query finishes, and
    :meth:`maybe_rebalance` between iterations.  The router never
    touches tokens — placement only decides WHERE a prefix chain is
    resident, so migrations and rebalances cannot change output.
    """

    def __init__(self, replicas: List[Replica],
                 assigner: OnlineClusterAssigner, *,
                 hot_ratio: float = 1.5, min_gap: int = 2) -> None:
        assert replicas, "need at least one replica"
        self.replicas = replicas
        self.assigner = assigner
        self.hot_ratio = float(hot_ratio)
        self.min_gap = int(min_gap)
        self.placement: Dict[Hashable, int] = {}   # cluster -> replica
        self.pending: Dict[Hashable, int] = {}     # cluster backlog
        self.cluster_routed: Dict[Hashable, int] = {}  # traffic per run
        self.migrations = 0
        self.shared_index: Optional[SharedSegmentIndex] = None
        self._spawn_rr = 0                         # tie-break cursor
        self._migrated: set = set()                # one move per cluster
                                                   # per run (no ping-pong)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def build(cls, engine, assigner: OnlineClusterAssigner, n: int, *,
              pool_budget_bytes: int, prefix_tokens_fn,
              segment_tokens_fn=None,
              host_tier_bytes: Optional[int] = None,
              hot_ratio: float = 1.5, min_gap: int = 2
              ) -> "ReplicaRouter":
        """N replica stacks over one model: replica 0 reuses ``engine``
        itself (its params/tokenizer/jit substrate), replicas 1..N-1
        are ``engine.clone()``s — same params BY REFERENCE, private
        arenas.  Every replica gets its own ``PrefixPool`` and host
        tier (the tier is mandatory: it is the migration transport);
        ``host_tier_bytes`` defaults to the pool budget."""
        from repro.core.prefix_pool import PrefixPool
        from repro.core.tiered import HostTier
        assert n >= 1, n
        tier_bytes = host_tier_bytes if host_tier_bytes is not None \
            else pool_budget_bytes
        replicas = []
        for i in range(n):
            eng = engine if i == 0 else engine.clone()
            eng.cache_mgr.reset_stats()
            sched = OnlineScheduler(eng, assigner,
                                    PrefixPool(pool_budget_bytes),
                                    prefix_tokens_fn,
                                    segment_tokens_fn=segment_tokens_fn)
            sched.pool.attach_host_tier(HostTier(tier_bytes))
            replicas.append(Replica(idx=i, engine=eng, scheduler=sched))
        router = cls(replicas, assigner, hot_ratio=hot_ratio,
                     min_gap=min_gap)
        # one shared content index across the fleet (DESIGN.md §15):
        # composition lookups resolve segments any replica prefilled
        index = SharedSegmentIndex()
        for r in replicas:
            r.scheduler.shared_index = index
        router.shared_index = index
        return router

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def route(self, embedding: np.ndarray, subgraph) -> Route:
        """Assign (shared assigner — call in GLOBAL arrival order) and
        place one query: affinity if the cluster is placed, else
        least-loaded spawn."""
        a = self.assigner.assign(embedding, subgraph)
        cid = a.cluster_id
        ridx = self.placement.get(cid)
        if ridx is None:
            ridx = self._least_loaded()
            self.placement[cid] = ridx
            self.replicas[ridx].spawns += 1
        else:
            self.replicas[ridx].affinity_hits += 1
        self.replicas[ridx].routed += 1
        self.pending[cid] = self.pending.get(cid, 0) + 1
        self.cluster_routed[cid] = self.cluster_routed.get(cid, 0) + 1
        return Route(replica=ridx, assignment=a)

    def _least_loaded(self) -> int:
        loads = [r.load for r in self.replicas]
        lo = min(loads)
        n = len(self.replicas)
        # round-robin among ties so a burst of cold spawns spreads even
        # while every backlog still reads zero
        for k in range(n):
            i = (self._spawn_rr + k) % n
            if loads[i] == lo:
                self._spawn_rr = (i + 1) % n
                return i
        return int(np.argmin(loads))      # unreachable

    def retire(self, replica: int, cluster_id: Hashable, n: int = 1
               ) -> None:
        """Account ``n`` finished queries of ``cluster_id`` on the
        replica that actually served them (which may differ from the
        cluster's CURRENT placement if it migrated mid-flight)."""
        self.replicas[replica].retired += n
        left = self.pending.get(cluster_id, 0) - n
        if left > 0:
            self.pending[cluster_id] = left
        else:
            self.pending.pop(cluster_id, None)

    # ------------------------------------------------------------------
    # rebalancing
    # ------------------------------------------------------------------
    def maybe_rebalance(self) -> Optional[Hashable]:
        """Migrate ONE cluster off the hottest replica when the load
        imbalance crosses the trigger; returns the migrated cluster id
        (None: balanced, or nothing movable).

        The candidate must have ZERO pending backlog: migration only
        redirects FUTURE arrivals (queries already queued stay where
        they were routed), so moving a backlogged cluster destroys its
        device-resident prefix on the hot replica while that replica
        STILL has its queries to serve — a re-prefill on the busiest
        engine, all cost and no relief.  Among the drained candidates
        the one with the most routed traffic this run is the best bet
        to keep receiving arrivals; clusters carrying over half the hot
        replica's traffic are excluded (moving the dominant cluster
        just swaps which replica is hot)."""
        if len(self.replicas) < 2:
            return None
        loads = [r.load for r in self.replicas]
        mean = sum(loads) / len(loads)
        hi = int(np.argmax(loads))
        lo = int(np.argmin(loads))
        # loads[lo] == 0: the coldest replica is DRAINED, so the gap is
        # a transient (the fleet is absorbing faster than arrivals) —
        # moving placements at it just thrashes the next trace's start
        if mean <= 0 or loads[lo] == 0 \
                or loads[hi] <= self.hot_ratio * mean \
                or loads[hi] - loads[lo] < self.min_gap:
            return None
        cap = max(1, self.replicas[hi].routed // 2)
        cands = [(self.cluster_routed.get(cid, 0), cid)
                 for cid, r in self.placement.items()
                 if r == hi and cid not in self._migrated
                 and self.pending.get(cid, 0) == 0
                 and 0 < self.cluster_routed.get(cid, 0) <= cap]
        if not cands:
            return None
        _, cid = max(cands, key=lambda t: t[0])
        self._migrated.add(cid)
        self.migrate(cid, hi, lo)
        return cid

    def migrate(self, cluster_id: Hashable, src: int, dst: int) -> int:
        """Move ``cluster_id``'s placement from ``src`` to ``dst`` and
        carry its resident chain segments along through the host
        round-trip: targeted demote on the source, ``HostSegment``
        handoff into the destination tier, lazy promote on the
        destination at the cluster's next ``ensure_chain``.  Segments
        that refuse to demote (pinned by an in-flight row, or shared
        ancestors still anchoring another cluster's chain) are simply
        skipped — the destination recomputes them through the ordinary
        miss path, so correctness never depends on the move landing.
        Returns the number of segments actually handed over."""
        s = self.replicas[src]
        d = self.replicas[dst]
        moved = 0
        # leaf-first: a demoted leaf un-anchors its parent for the next
        # iteration, peeling the chain bottom-up in one pass
        for key in reversed(self.chain_path(cluster_id)):
            if not s.scheduler.pool.demote_to_host(key):
                continue
            hseg = s.scheduler.pool.tier.pop(key)
            if hseg is not None and d.scheduler.pool.tier.admit(hseg):
                moved += 1
                # the key left the source stack entirely: retract its
                # content-registry entries (and index publications)
                s.scheduler._invalidate_key(key)
        s.stats.record_migration(out=moved)
        d.stats.record_migration(into=moved)
        self.placement[cluster_id] = dst
        self.migrations += 1
        return moved

    def chain_path(self, cluster_id: Hashable) -> List[Hashable]:
        """The cluster's pool keys root→leaf — same key scheme as
        ``OnlineScheduler.ensure_chain``."""
        c = self.assigner.clusters[cluster_id]
        if c.chain is not None:
            return [("seg", node) for node in c.chain.keys]
        return [cluster_id]

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def reset_counters(self) -> None:
        """Zero the per-run load/affinity/migration counters and the
        virtual clocks; the placement map, cluster population, and every
        replica's engine/jit substrate — the warmth — are kept.  Call
        before a timed replay over a warmed router."""
        for r in self.replicas:
            r.routed = r.retired = r.affinity_hits = r.spawns = 0
            r.clock = 0.0
        self.pending.clear()
        self.cluster_routed.clear()
        self.migrations = 0
        self._migrated.clear()

    @property
    def makespan(self) -> float:
        """The slowest replica's virtual clock after a trace — the
        denominator of the scaling bench's throughput."""
        return max(r.clock for r in self.replicas)

    @property
    def loads(self) -> List[int]:
        return [r.load for r in self.replicas]

    def imbalance(self) -> float:
        """Max/mean replica load — 1.0 is perfectly balanced (0 when
        idle).  The aggregate gauge the skew bench reads."""
        loads = [r.routed for r in self.replicas]
        mean = sum(loads) / len(loads)
        return max(loads) / mean if mean > 0 else 0.0

    def affinity_hit_rate(self, replica: int) -> float:
        """Of the queries routed to ``replica``, how many landed on a
        cluster already placed there (prefix locality, per replica)."""
        r = self.replicas[replica]
        return r.affinity_hits / r.routed if r.routed else 0.0
