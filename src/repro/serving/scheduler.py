"""Online cluster-serving scheduler: streaming queries -> pooled prefixes.

The offline planner (``core/planner.py::plan_batch``) needs every query
embedding up front: it cuts one dendrogram and the engine serves the
clusters one at a time.  Under streaming traffic queries arrive one by
one, so this module replaces the one-shot cut with three online pieces
(DESIGN.md §7):

* ``OnlineClusterAssigner`` — incremental nearest-representative
  assignment.  Each arriving query joins the cluster whose
  representative centroid is nearest if that distance is within
  ``threshold``; otherwise it SPAWNS a new cluster (whose
  representative subgraph is the query's own retrieved subgraph, and
  whose prefix must be prefilled once).  ``threshold=inf`` never
  spawns after the first cluster exists; ``max_clusters`` caps the
  population, after which every query joins its nearest cluster.
* ``ArrivalQueue`` — a time-ordered arrival buffer that the serving
  loop drains into slot-limited micro-batches (``drain``): take every
  query that has arrived by ``now``, up to ``max_slots``.
* ``OnlineScheduler`` — glues assigner + ``PrefixPool`` + engine: for a
  drained micro-batch it assigns every query, materializes each
  cluster's ``PrefixState`` through the pool (hit = reuse, miss =
  prefill + admit, possibly re-prefill after an eviction), and serves
  the whole mixed batch in ONE ``engine.serve(requests)`` call — the
  decode batch mixes members of different clusters instead of idling
  between clusters, each row walking its own cluster's prefix page
  table over the shared block arena (DESIGN.md §8).  The engine picks
  the backend (paged / dense fallback); this module never branches on
  architecture.  ``serve_continuous`` is the continuous-batching
  counterpart (DESIGN.md §9): it ADMITS a drained group into a
  ``ContinuousEngine``'s free slots (states pinned per row, released
  at retirement) and leaves decode chunking to the caller's event
  loop.

Exactness contract: the multi-prefix path produces token-identical
outputs to serving each cluster separately through the dense cascade
(tests/test_scheduler.py); only scheduling changes, never math.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache import SegmentComposition
from repro.core.planner import (BatchPlan, ChainSpec, PrefixTreePlan,
                                plan_composition)
from repro.core.prefix_pool import PrefixPool
from repro.core.subgraph import Subgraph


# ======================================================================
# online cluster assignment
# ======================================================================
@dataclasses.dataclass
class OnlineCluster:
    """A live cluster: frozen representative + assignment centroid.

    ``chain`` (tree serving, DESIGN.md §10): the root→leaf chain spec —
    pool keys + nested segment contents — this cluster's prefix is
    materialized through.  ``None`` = flat single-segment prefix (the
    representative's textualization, the historical behavior)."""
    cluster_id: int
    centroid: np.ndarray        # [dim] assignment anchor (frozen at spawn
                                # or seeded from an offline plan)
    representative: Subgraph    # subgraph whose textualization is the prefix
    members: int = 0
    chain: Optional[ChainSpec] = None


@dataclasses.dataclass
class Assignment:
    """Result of assigning one query embedding."""
    cluster_id: int
    is_new: bool                # True = this query spawned the cluster
    distance: float             # Euclidean distance to the joined centroid


class OnlineClusterAssigner:
    """Incremental nearest-representative cluster assignment.

    The centroid of a cluster is FROZEN once the cluster exists: its
    representative prefix KV is already prefilled, so drifting the
    anchor would decouple "what the query matched" from "what prefix it
    is served with".  Spawning is the adaptation mechanism — a query
    farther than ``threshold`` from every centroid opens a new cluster
    (and pays one representative prefill).

    ``threshold``: spawn distance (Euclidean, same metric as the
    offline dendrogram).  ``math.inf`` disables spawning once at least
    one cluster exists.  ``max_clusters``: hard cap; at the cap every
    query joins its nearest cluster regardless of distance (mirrors the
    offline planner's fixed ``num_clusters`` cut).
    """

    def __init__(self, threshold: float = math.inf,
                 max_clusters: Optional[int] = None) -> None:
        assert threshold >= 0.0, threshold
        self.threshold = float(threshold)
        self.max_clusters = max_clusters
        self.clusters: List[OnlineCluster] = []
        self._centroids: Optional[np.ndarray] = None   # [C, dim] cache

    # ------------------------------------------------------------------
    @classmethod
    def from_plan(cls, plan: BatchPlan, embeddings: np.ndarray,
                  threshold: float = math.inf,
                  max_clusters: Optional[int] = None
                  ) -> "OnlineClusterAssigner":
        """Seed the online assigner from an offline ``plan_batch`` cut:
        one cluster per plan entry, centroid = mean member embedding,
        representative = the plan's union-merged subgraph.  This is the
        warm-start path (bootstrap from yesterday's traffic) and the
        bridge the offline-vs-online equivalence test walks."""
        a = cls(threshold=threshold, max_clusters=max_clusters)
        for cp in plan.clusters:
            centroid = np.mean(np.asarray(embeddings)[cp.member_indices],
                               axis=0)
            a.clusters.append(OnlineCluster(
                cluster_id=len(a.clusters), centroid=centroid,
                representative=cp.representative,
                members=len(cp.member_indices)))
        return a

    @classmethod
    def from_tree_plan(cls, plan: PrefixTreePlan, embeddings: np.ndarray,
                       threshold: float = math.inf,
                       max_clusters: Optional[int] = None
                       ) -> "OnlineClusterAssigner":
        """Seed the assigner from a multi-level prefix-tree plan
        (DESIGN.md §10): one online cluster per tree LEAF, carrying the
        root→leaf chain spec the scheduler materializes segment by
        segment.  Assignment itself is unchanged — queries join the
        nearest leaf centroid; the tree only changes how that leaf's
        prefix is stored.  Spawned clusters (past the seed population)
        fall back to flat single-segment prefixes: an unseen cluster
        has no dendrogram ancestors to share with."""
        a = cls(threshold=threshold, max_clusters=max_clusters)
        for leaf in plan.leaves:
            node = plan.nodes[leaf]
            centroid = np.mean(np.asarray(embeddings)[node.member_indices],
                               axis=0)
            a.clusters.append(OnlineCluster(
                cluster_id=len(a.clusters), centroid=centroid,
                representative=node.content,
                members=len(node.member_indices),
                chain=plan.chain(leaf)))
        return a

    # ------------------------------------------------------------------
    def _centroid_matrix(self) -> np.ndarray:
        """[C, dim] stacked centroids; centroids are frozen, so the
        stack is invalidated only when a cluster spawns (the per-query
        hot path stays one vectorized norm, not an O(C) Python loop)."""
        if self._centroids is None or len(self._centroids) != len(
                self.clusters):
            self._centroids = np.stack([c.centroid for c in self.clusters])
        return self._centroids

    def nearest(self, embedding: np.ndarray) -> Tuple[int, float]:
        """(cluster_id, distance) of the nearest live centroid."""
        assert self.clusters, "no clusters yet"
        emb = np.asarray(embedding, dtype=np.float64)
        dists = np.linalg.norm(self._centroid_matrix() - emb[None, :], axis=1)
        i = int(np.argmin(dists))
        return self.clusters[i].cluster_id, float(dists[i])

    def assign(self, embedding: np.ndarray,
               subgraph: Optional[Subgraph] = None) -> Assignment:
        """Assign one query; may spawn a cluster (see class docstring).

        ``subgraph`` is the query's retrieved subgraph — required only
        when a spawn is possible (it becomes the new representative).
        """
        emb = np.asarray(embedding, dtype=np.float64)
        if self.clusters:
            cid, dist = self.nearest(emb)
            at_cap = (self.max_clusters is not None
                      and len(self.clusters) >= self.max_clusters)
            if dist <= self.threshold or at_cap:
                c = self.clusters[cid]
                c.members += 1
                return Assignment(cluster_id=cid, is_new=False,
                                  distance=dist)
        if subgraph is None:
            raise ValueError("spawning a cluster requires the query's "
                             "subgraph (it becomes the representative)")
        c = OnlineCluster(cluster_id=len(self.clusters), centroid=emb,
                          representative=subgraph, members=1)
        self.clusters.append(c)
        return Assignment(cluster_id=c.cluster_id, is_new=True,
                          distance=0.0)

    def representative(self, cluster_id: int) -> Subgraph:
        return self.clusters[cluster_id].representative


# ======================================================================
# arrival queue / micro-batching
# ======================================================================
@dataclasses.dataclass(order=True)
class Arrival:
    """One queued request: ordered by (arrival time, sequence number)."""
    time_s: float
    seq: int
    payload: Any = dataclasses.field(compare=False)


class ArrivalQueue:
    """Time-ordered arrival buffer drained into slot-limited batches.

    ``push`` enqueues a request with its arrival timestamp; ``drain``
    pops every request that has arrived by ``now``, oldest first, up to
    ``max_slots`` — the micro-batch the scheduler serves next.  FIFO
    within equal timestamps (the sequence number breaks ties), so no
    request can starve.
    """

    def __init__(self) -> None:
        self._heap: List[Arrival] = []
        self._seq = 0

    def push(self, time_s: float, payload: Any) -> None:
        heapq.heappush(self._heap, Arrival(float(time_s), self._seq, payload))
        self._seq += 1

    def __len__(self) -> int:
        return len(self._heap)

    def next_arrival(self) -> Optional[float]:
        """Timestamp of the oldest queued request (None when empty)."""
        return self._heap[0].time_s if self._heap else None

    def drain(self, now: float, max_slots: int) -> List[Arrival]:
        """Pop up to ``max_slots`` requests with ``time_s <= now``."""
        out: List[Arrival] = []
        while self._heap and len(out) < max_slots \
                and self._heap[0].time_s <= now:
            out.append(heapq.heappop(self._heap))
        return out

    def peek(self, now: float, limit: Optional[int] = None) -> List[Arrival]:
        """Non-popping view of up to ``limit`` requests with
        ``time_s <= now``, oldest first — the queued-but-not-admitted
        overflow the serving loop speculatively prefetches for
        (DESIGN.md §12) while the current batch occupies the slots."""
        out = sorted((a for a in self._heap if a.time_s <= now))
        return out if limit is None else out[:limit]


# ======================================================================
# the scheduler: assigner + pool + engine
# ======================================================================
@dataclasses.dataclass
class AdmittedQuery:
    """Per-query outcome of one CONTINUOUS admission (DESIGN.md §9).
    Travels as the row's payload through ``ContinuousEngine`` and comes
    back in its ``RowResult`` at retirement — which also releases this
    row's pool pin (``on_retire``)."""
    payload: Any                # caller's own handle
    cluster_id: int
    prefix_len: int             # tokens in the cluster prefix it reused
    pool_hit: bool              # prefix served from the pool
    spawned: bool               # this query opened the cluster
    prefix_share_s: float       # share of any prefix prefill this admission paid
    # pool keys this row pinned — the full root→leaf path for a chain
    # cluster, [cluster_id] for a flat one; released at retirement
    pin_keys: List[Any] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServedQuery:
    """Per-query outcome of one scheduled micro-batch."""
    tokens: List[int]           # generated token ids
    cluster_id: int
    prefix_len: int             # tokens in the cluster prefix it reused
    pool_hit: bool              # prefix served from the pool
    spawned: bool               # this query opened the cluster
    prefix_share_s: float       # share of any prefix prefill this batch paid
    prefill_s: float            # this member's share of the batched prefill
    decode_s: float             # this member's share of the batched decode


class OnlineScheduler:
    """Serve micro-batches of streaming queries from a prefix pool.

    Composition root of the online path: ``assigner`` decides which
    cluster a query belongs to, ``pool`` owns the live ``PrefixState``s
    under the byte budget, ``engine.serve`` runs one mixed batch
    against all the prefixes it touches at once.

    ``prefix_tokens_fn(representative) -> List[int]`` builds the prefix
    token ids for a cluster representative (the pipeline passes its
    textualize+tokenize closure, keeping this module free of tokenizer
    and retriever dependencies).  ``segment_tokens_fn(content, base) ->
    List[int]`` is the chain counterpart (DESIGN.md §10): the token ids
    of ONE chain segment — ``content``'s delta over ``base`` (``base
    is None`` = the root segment, which also carries the soft prompt);
    required only when the assigner holds chain clusters.
    """

    def __init__(self, engine, assigner: OnlineClusterAssigner,
                 pool: PrefixPool,
                 prefix_tokens_fn: Callable[[Subgraph], List[int]],
                 segment_tokens_fn: Optional[Callable] = None) -> None:
        self.engine = engine
        self.assigner = assigner
        self.pool = pool
        self.prefix_tokens_fn = prefix_tokens_fn
        self.segment_tokens_fn = segment_tokens_fn
        # segment composition (DESIGN.md §14): ``compose_frac`` arms the
        # composed admission path (None = chains only, the historical
        # behavior); the registry maps segment token CONTENT to the pool
        # key it is cached under, so a cluster can splice a segment some
        # other cluster prefilled at a different base position
        self.compose_frac: Optional[float] = None
        self._seg_registry: dict = {}
        # drift-scored recomputation (DESIGN.md §15): when set, spliced
        # segments re-prefill their top-``compose_budget`` drift-scored
        # token blocks instead of the fixed ``compose_frac`` leading
        # window (the frac still covers segments when the budget is off)
        self.compose_budget: Optional[int] = None
        # composed admission policy: "greedy" engages every re-based
        # splice (historical behavior); "cost" additionally weighs the
        # per-arrival fresh-token bill against the chain's one-time
        # prefill using observed repeat rates (DESIGN.md §15)
        self.compose_admission: str = "greedy"
        # reverse of _seg_registry: pool key -> content tuples mapped to
        # it, so a hard eviction can retract exactly its own entries
        self._key_contents: dict = {}
        # cross-replica content index (DESIGN.md §15): installed by
        # ``ReplicaRouter.build``; None = per-replica registry only
        self.shared_index = None
        # pool accounting flows into the engine's serving stats window
        self.pool.stats = engine.cache_mgr.stats
        self.pool.on_hard_evict = self._invalidate_key
        # paged backend: block-allocator pressure evicts cold pooled
        # prefixes (admission and HBM budget are one mechanism); the
        # engine hands captured gap spans to this scheduler's registry
        if getattr(engine, "block_pool", None) is not None:
            self.pool.attach_block_pool(engine.block_pool)
            engine.gap_admit = self.gap_admit

    # ------------------------------------------------------------------
    # content-addressed segment registry (DESIGN.md §14/§15)
    # ------------------------------------------------------------------
    def _register_segment(self, content: tuple, pool_key) -> None:
        """Map segment token CONTENT to the pool key holding its KV,
        maintain the reverse map hard-eviction invalidation walks, and
        publish to the cross-replica index when one is installed."""
        self._seg_registry[content] = pool_key
        self._key_contents.setdefault(pool_key, set()).add(content)
        if self.shared_index is not None:
            self.shared_index.publish(content, self, pool_key)

    def _invalidate_key(self, pool_key) -> None:
        """``PrefixPool.on_hard_evict`` hook: the entry under
        ``pool_key`` is gone with no host copy, so every content tuple
        resolving to it must be forgotten — a dangling registry entry
        would send ``try_compose`` to a key whose blocks were recycled
        (the bug this hook exists to prevent; see
        tests/test_composition.py regression)."""
        for content in self._key_contents.pop(pool_key, ()):
            if self._seg_registry.get(content) == pool_key:
                del self._seg_registry[content]
            if self.shared_index is not None:
                self.shared_index.retract(content, self)

    def gap_admit(self, tokens: tuple, state) -> bool:
        """Engine callback (DESIGN.md §15): adopt one captured
        composition gap span as a content-addressed pool entry so
        repeat traffic over the same content splices it instead of
        re-prefilling it.  Returns False — caller releases the state —
        when the content is already resolvable through the registry (a
        duplicate capture would spend blocks on bits we have)."""
        content = tuple(tokens)
        old = self._seg_registry.get(content)
        if old is not None and (
                self.pool.peek(old) is not None
                or (self.pool.tier is not None
                    and self.pool.tier.peek(old) is not None)):
            return False
        key = ("gap", content)
        self.pool.put(key, state)
        self._register_segment(content, key)
        return True

    # ------------------------------------------------------------------
    def ensure_state(self, cluster_id: int, pin: bool = False):
        """Pool lookup with miss handling: (state, hit, prefill_s).

        Miss (cold cluster or evicted entry) first tries to PROMOTE the
        segment back from the host tier (DESIGN.md §12) — bitwise the
        blocks it was demoted from, so a promoted state counts as a hit
        (the tokens are served from the cache hierarchy, not
        recomputed).  Only a double miss (device AND host) re-prefills
        the representative prefix and re-admits it; the pool counts
        that readmission as a re-prefill when the key was evicted
        before.  ``pin=True`` acquires the state with an in-flight
        reference held atomically (materialize-and-pin), so a later
        admission in the same batch can never evict a state this batch
        already claimed — the caller must ``pool.release`` it after
        serving.
        """
        state = self.pool.get(cluster_id, pin=pin)
        if state is not None:
            return state, True, 0.0
        state = self.pool.promote(cluster_id, pin=pin)
        if state is not None:
            return state, True, 0.0
        payload = self.prefix_tokens_fn(
            self.assigner.representative(cluster_id))
        # the pipeline may return (tokens, soft_prompt_embeds)
        toks, soft = payload if isinstance(payload, tuple) else (payload, None)
        state, dt = self.engine.prefill_prefix(toks, soft)
        self.pool.put(cluster_id, state, prefill_s=dt, pin=pin)
        if soft is None:
            self._register_segment(tuple(toks), cluster_id)
        return state, False, dt

    def ensure_chain(self, cluster_id: int, pin: bool = False):
        """Materialize a cluster's full prefix CHAIN through the pool:
        ``(leaf_state, leaf_hit, prefill_s, pin_keys)`` (DESIGN.md §10).

        Walks the path root→leaf; each segment is its own pool entry
        (key ``("seg", node_id)`` — shared by every sibling path, which
        is the whole point).  A resident segment is reused (ancestor
        hits are the tree layout's savings and are recorded per level);
        a missing one is prefilled as an EXTENSION of the parent state,
        so only the path's cold remainder is ever computed.  The
        tree-aware eviction order (leaf before ancestor,
        ``core/prefix_pool.py``) guarantees a resident descendant never
        dangles below an evicted ancestor, so the forward walk never
        recomputes content a deeper segment still holds.  ``pin=True``
        pins EVERY path entry (one ref per segment per call); callers
        release the returned ``pin_keys``.  Flat clusters delegate to
        ``ensure_state`` with ``pin_keys=[cluster_id]``.
        """
        c = self.assigner.clusters[cluster_id]
        if c.chain is None:
            st, hit, dt = self.ensure_state(cluster_id, pin=pin)
            return st, hit, dt, [cluster_id]
        assert self.segment_tokens_fn is not None, \
            "chain clusters need segment_tokens_fn (pipeline wiring)"
        stats = self.engine.cache_mgr.stats
        n = len(c.chain.keys)
        parent, prefill_s, keys, hit = None, 0.0, [], False
        try:
            for i, (node, content) in enumerate(zip(c.chain.keys,
                                                    c.chain.contents)):
                key = ("seg", node)
                st = self.pool.get(key, pin=pin)
                if st is None:
                    # host-tier promotion before recompute: the walk is
                    # root→leaf, so the parent is device-resident by the
                    # time its child promotes (chain-aware re-linking)
                    st = self.pool.promote(key, parent=parent, pin=pin)
                hit = st is not None
                if not hit:
                    base = c.chain.contents[i - 1] if i else None
                    payload = self.segment_tokens_fn(content, base)
                    toks, soft = (payload if isinstance(payload, tuple)
                                  else (payload, None))
                    if parent is None:
                        st, dt = self.engine.prefill_prefix(toks, soft)
                    else:
                        st, dt = self.engine.prefill_prefix_extension(
                            parent, toks)
                    self.pool.put(key, st, prefill_s=dt, pin=pin)
                    if soft is None:
                        self._register_segment(tuple(toks), key)
                    prefill_s += dt
                stats.record_tree_segment(i, st.segment_len, hit=hit,
                                          leaf=(i == n - 1))
                keys.append(key)
                parent = st
        except BaseException:
            # a mid-chain failure (e.g. OutOfBlocks on an extension)
            # must drop the pins this walk already took — the caller's
            # unwind only covers keys it has been handed
            if pin:
                for key in keys:
                    self.pool.release(key)
            raise
        self.pool.observe_tree_residency()
        return parent, hit, prefill_s, keys

    # ------------------------------------------------------------------
    # segment composition admission (DESIGN.md §14)
    # ------------------------------------------------------------------
    def try_compose(self, cluster_id: int, pin: bool = True,
                    probe_tokens: Sequence[int] = ()
                    ) -> Optional[Tuple[SegmentComposition, List[Any]]]:
        """Plan a ``SegmentComposition`` for this cluster from
        pool-resident segments; ``(comp, pinned_pool_keys)`` or None.

        Engages ONLY when composition offers something the chain path
        cannot: at least one RE-BASED splice — a resident segment whose
        cached base position differs from its offset in this cluster's
        prompt (cached under another cluster's chain, found through the
        content registry — or through the cross-replica shared index,
        which migrates the segment here over the host-tier transport).
        Everything else — full own-chain residency, cold paths,
        exact-offset-only hits — returns None and falls back to
        ``ensure_chain``, which serves it equally well AND caches the
        cold remainder for later.  With ``compose_budget`` set, spliced
        segments carry drift-scored recompute masks (DESIGN.md §15)
        scored against the plan's gap tokens plus ``probe_tokens`` (the
        arriving query's suffix).  ``compose_admission == "cost"`` may
        additionally DECLINE a viable engage when observed repeat
        traffic makes the chain path cheaper.  Returned pins follow
        ``serve_batch``'s contract: caller releases every key."""
        if self.compose_frac is None or self.segment_tokens_fn is None:
            return None
        c = self.assigner.clusters[cluster_id]
        if c.chain is None:
            return None        # flat prefix: one segment, own pool entry
        seg_toks: List[List[int]] = []
        for i, content in enumerate(c.chain.contents):
            base = c.chain.contents[i - 1] if i else None
            payload = self.segment_tokens_fn(content, base)
            toks, soft = (payload if isinstance(payload, tuple)
                          else (payload, None))
            if soft is not None:
                return None    # composition serves token segments only
            seg_toks.append(list(toks))
        pinned: List[Any] = []

        def lookup(key):
            pool_key = self._seg_registry.get(key)
            if pool_key is None and self.shared_index is not None:
                # another replica may hold this content: fetch moves it
                # into OUR host tier over the migration transport and
                # registers it locally; the promote path below then
                # onboards it like any demoted segment (DESIGN.md §15)
                pool_key = self.shared_index.fetch(key, self)
            if pool_key is None:
                return None
            st = self.pool.get(pool_key, pin=pin)
            if st is None and self.pool.tier is not None:
                # demoted since it was registered: promote it back — a
                # promoted segment carries its base-position metadata
                # (prefix_len/seg_len) bitwise, so it composes exactly
                # like a never-evicted one (DESIGN.md §12/§14).  Chain
                # segments promote only under a resident parent (the
                # tier's linkage rule); otherwise this stays a gap.
                hseg = self.pool.tier.peek(pool_key)
                parent = (self.pool.get(hseg.parent_key)
                          if hseg is not None
                          and hseg.parent_key is not None else None)
                if hseg is not None and (hseg.parent_key is None
                                         or parent is not None):
                    st = self.pool.promote(pool_key, parent=parent,
                                           pin=pin)
            if st is None:
                return None    # registered but evicted since
            if pin:
                pinned.append(pool_key)
            return st

        scorer = None
        if self.compose_budget is not None:
            probe = tuple(probe_tokens)
            scorer = lambda c: self.engine.drift_scores(c, probe)
        comp = plan_composition(
            seg_toks, lookup, recompute_frac=self.compose_frac,
            recompute_budget=self.compose_budget, scorer=scorer,
            block_size=getattr(self.engine, "block_size", 0) or 0)
        if comp is not None and any(
                s.target_offset != s.state.base_pos for s in comp.segments):
            if not self._compose_declined(cluster_id, comp):
                return comp, pinned
            self.engine.cache_mgr.stats.record_compose_decline()
        if pin:
            for key in pinned:
                self.pool.release(key)
        return None

    def _compose_declined(self, cluster_id: int,
                          comp: SegmentComposition) -> bool:
        """Composition-aware admission cost model (DESIGN.md §15).

        The composed path pays its fresh tokens — gaps plus drift /
        window recompute spans — on EVERY arrival of this cluster
        (gap spans may get captured opportunistically, but the model
        prices the guaranteed-cost worst case), while the chain path
        pays the full prompt ONCE and serves repeats from the pool.
        Under the doubling heuristic (``k`` arrivals seen ⇒ expect
        ``~k`` more) the engage is declined when the repeat-weighted
        fresh-token bill exceeds the one-shot chain prefill."""
        if self.compose_admission != "cost":
            return False
        seen = self.engine.cache_mgr.stats.cluster_arrivals.get(
            cluster_id, 0)
        expected = max(1, seen)       # doubling heuristic
        fresh = sum(len(t) for _, t in comp.fresh_spans())
        return fresh * (1 + expected) > comp.total_len

    # ------------------------------------------------------------------
    # speculative host→device prefetch (DESIGN.md §12)
    # ------------------------------------------------------------------
    def prefetch(self, embeddings: Sequence[np.ndarray]) -> int:
        """Kick off host-tier promotions for queries that are TAGGED but
        not yet at the queue front: each embedding is probed against the
        live centroids (``assigner.nearest`` — non-mutating, no spawn,
        no member count), and any host-resident segment on the matched
        cluster's chain is promoted NOW, so the async ``device_put``
        overlaps the queue wait instead of the serving batch.  Promoted
        entries are admitted unpinned with ``prefetched=True``; the
        first real ``get`` hit consumes the flag
        (``CacheStats.prefetch_hit_rate`` — speculation precision).

        Prefetch never computes: the walk stops at the first segment
        that is neither device- nor host-resident (promoting below a
        cold ancestor is impossible anyway — chain promotion re-links
        through the resident parent).  Probes use ``pool.peek``, so a
        prefetch is invisible to hit/miss accounting.  Returns the
        number of promotions started.
        """
        if not self.assigner.clusters:
            return 0
        tier = self.pool.tier
        if tier is None or len(tier) == 0:
            return 0
        started = 0
        for emb in embeddings:
            cid, _ = self.assigner.nearest(emb)
            c = self.assigner.clusters[cid]
            path = ([("seg", node) for node in c.chain.keys]
                    if c.chain is not None else [cid])
            parent = None
            for key in path:
                st = self.pool.peek(key)
                if st is None and tier.peek(key) is not None:
                    st = self.pool.promote(key, parent=parent,
                                           prefetched=True)
                    if st is not None:
                        started += 1
                if st is None:
                    break        # cold segment: prefetch never computes
                parent = st
        return started

    def _drain_tier(self) -> float:
        """Sync point for in-flight promotion transfers: block on every
        parked ``device_put`` and record the residual wait — ~0 when
        the batch's own dispatched work already covered the transfer
        (the overlap claim, measured per batch)."""
        tier = self.pool.tier
        return tier.drain_pending() if tier is not None else 0.0

    def serve_batch(self, embeddings: Sequence[np.ndarray],
                    subgraphs: Sequence[Subgraph],
                    suffix_token_lists: Sequence[List[int]],
                    assignments: Optional[Sequence[Assignment]] = None
                    ) -> List[ServedQuery]:
        """Assign, materialize prefixes, and serve one micro-batch.

        All queries are served in ONE batched prefill + decode
        (``engine.serve``); members of different clusters share the
        decode step, each walking its own cluster's prefix page table
        (paged backend) — the engine, not this scheduler, decides the
        backend, so stateful and cross-attention architectures take the
        same code path here.  Prefix-prefill cost is attributed to the
        queries of the cluster that caused it (uniform share), batched
        prefill/decode to every member of its sub-batch share.

        ``assignments`` bypasses the internal ``assigner.assign`` pass:
        the ``ReplicaRouter`` assigns clusters once, globally, at
        arrival time (DESIGN.md §13) and hands each replica's scheduler
        the pre-made ``Assignment`` records — cluster evolution must
        not depend on how arrivals interleave across replicas.
        """
        from repro.serving.engine import Request
        n = len(suffix_token_lists)
        assert len(embeddings) == n and len(subgraphs) == n
        assigns = list(assignments) if assignments is not None else \
            [self.assigner.assign(e, sg)
             for e, sg in zip(embeddings, subgraphs)]
        stats = self.engine.cache_mgr.stats
        sfx_of: dict = {}       # cid -> first member's suffix (drift probe)
        for a, s in zip(assigns, suffix_token_lists):
            stats.record_arrival(a.cluster_id)
            sfx_of.setdefault(a.cluster_id, list(s))
        order = sorted(set(a.cluster_id for a in assigns))
        states, hits, prefill_costs = {}, {}, {}
        comps: dict = {}                 # cid -> SegmentComposition
        pinned: List[Any] = []           # pool keys (full path per cluster)
        try:
            # materialize-and-pin: each state is pinned the moment it is
            # acquired — for a chain cluster every PATH segment is
            # pinned (root to leaf) — so a later cluster's admission in
            # this same loop cannot evict a state this batch already
            # claimed.  A cluster that can splice resident foreign
            # segments takes the composed path instead (DESIGN.md §14).
            for cid in order:
                ct = self.try_compose(cid, pin=True,
                                      probe_tokens=sfx_of.get(cid, ()))
                if ct is not None:
                    comps[cid], keys = ct
                    pinned.extend(keys)
                    states[cid], hits[cid], prefill_costs[cid] = \
                        None, True, 0.0
                    continue
                st, hit, dt, keys = self.ensure_chain(cid, pin=True)
                pinned.extend(keys)
                states[cid], hits[cid], prefill_costs[cid] = st, hit, dt
            outs, t = self.engine.serve(
                [Request(suffix_tokens=list(s),
                         prefix=states[a.cluster_id],
                         composition=comps.get(a.cluster_id))
                 for a, s in zip(assigns, suffix_token_lists)])
        finally:
            # promotion transfers dispatched for/during this batch have
            # been overlapped by the serve itself; drain what is left
            self._drain_tier()
            for key in pinned:
                self.pool.release(key)
        members_of = {cid: sum(1 for a in assigns if a.cluster_id == cid)
                      for cid in order}
        served = []
        for i, a in enumerate(assigns):
            share = prefill_costs[a.cluster_id] / members_of[a.cluster_id]
            cid = a.cluster_id
            plen = (comps[cid].total_len if cid in comps
                    else states[cid].prefix_len)
            served.append(ServedQuery(
                tokens=outs[i], cluster_id=a.cluster_id,
                prefix_len=plen,
                pool_hit=hits[a.cluster_id], spawned=a.is_new,
                prefix_share_s=share,
                prefill_s=t["prefill_share"][i],
                decode_s=t["decode_share"][i]))
        return served

    # ------------------------------------------------------------------
    def serve_continuous(self, cont, embeddings: Sequence[np.ndarray],
                         subgraphs: Sequence[Subgraph],
                         suffix_token_lists: Sequence[List[int]],
                         payloads: Optional[Sequence[Any]] = None,
                         now: float = 0.0,
                         assignments: Optional[Sequence[Assignment]] = None
                         ) -> Tuple[List[AdmittedQuery], float]:
        """Assign + materialize prefixes + ADMIT one group of arrivals
        into ``cont`` (a ``ContinuousEngine``) — the continuous
        counterpart of ``serve_batch`` (DESIGN.md §9).  Decode is NOT
        run here: the caller's event loop interleaves ``cont.step()``
        chunks with further admissions, which is exactly what removes
        the drain-serve loop's head-of-line blocking.

        Every row takes its own pool pin (first acquisition through
        ``ensure_state(pin=True)``, additional members via ``pin``);
        the pin is released per row at retirement (``on_retire``), so a
        cluster stays unevictable exactly as long as any of its members
        is in flight.  Returns ``(admitted, prefill_s)`` — the
        ``AdmittedQuery`` records come back as ``RowResult.payload``
        from ``cont.pop_retired()``.
        """
        from repro.serving.engine import Request
        n = len(suffix_token_lists)
        assert len(embeddings) == n and len(subgraphs) == n
        assert n <= cont.free_slots, (n, cont.free_slots)
        if payloads is None:
            payloads = [None] * n
        assigns = list(assignments) if assignments is not None else \
            [self.assigner.assign(e, sg)
             for e, sg in zip(embeddings, subgraphs)]
        order = sorted(set(a.cluster_id for a in assigns))
        stats = self.engine.cache_mgr.stats
        sfx_of: dict = {}       # cid -> first member's suffix (drift probe)
        for a, s in zip(assigns, suffix_token_lists):
            stats.record_arrival(a.cluster_id)
            sfx_of.setdefault(a.cluster_id, list(s))
        members_of = {cid: sum(1 for a in assigns if a.cluster_id == cid)
                      for cid in order}
        states, hits, costs, paths = {}, {}, {}, {}
        comps: dict = {}                # cid -> SegmentComposition
        pins: List[Any] = []            # one pool key per pin taken
        try:
            for cid in order:
                # the full root→leaf path is pinned per ROW: a cluster's
                # whole chain stays unevictable exactly as long as any
                # of its members is in flight (DESIGN.md §10).  Clusters
                # that splice resident foreign segments pin those
                # segments instead (DESIGN.md §14).
                ct = self.try_compose(cid, pin=True,
                                      probe_tokens=sfx_of.get(cid, ()))
                if ct is not None:
                    comps[cid], keys = ct
                else:
                    st, hit, dt, keys = self.ensure_chain(cid, pin=True)
                    states[cid], hits[cid], costs[cid] = st, hit, dt
                pins.extend(keys)
                paths[cid] = keys
                for _ in range(members_of[cid] - 1):
                    for key in keys:
                        self.pool.pin(key)
                        pins.append(key)
            admitted = [AdmittedQuery(
                payload=payloads[i], cluster_id=a.cluster_id,
                prefix_len=(comps[a.cluster_id].total_len
                            if a.cluster_id in comps
                            else states[a.cluster_id].prefix_len),
                pool_hit=(True if a.cluster_id in comps
                          else hits[a.cluster_id]),
                spawned=a.is_new,
                prefix_share_s=(costs.get(a.cluster_id, 0.0)
                                / members_of[a.cluster_id]),
                pin_keys=list(paths[a.cluster_id]))
                for i, a in enumerate(assigns)]
            prefill_s = cont.admit(
                [Request(suffix_tokens=list(s),
                         prefix=states.get(a.cluster_id),
                         composition=comps.get(a.cluster_id))
                 for a, s in zip(assigns, suffix_token_lists)],
                payloads=admitted, now=now,
                on_retire=self._release_pins)
        except BaseException:
            for key in pins:
                self.pool.release(key)
            raise
        self._drain_tier()
        return admitted, prefill_s

    def _release_pins(self, aq: AdmittedQuery) -> None:
        """Drop one retired row's pool pins (its full pinned path)."""
        for key in aq.pin_keys:
            self.pool.release(key)
