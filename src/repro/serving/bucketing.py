"""Shape bucketing for the serving engine (DESIGN.md §3).

Lengths are data, shapes are buckets: every serving shape — suffix
length, member batch, cache capacity, page-table width — is rounded up
to a small family of buckets so a handful of compiled executables serve
any workload.  One module owns all of the rounding rules; the engine,
the paged KV pool, and the benchmarks import from here instead of
keeping private copies (three of which had drifted apart by PR 2).

Buckets:

* ``bucket_len``     — sequence lengths: next multiple of ``bucket``.
* ``bucket_pow2``    — batch / pool / page-table widths: next power of
                       two (compiled-executable count stays O(log n)).
* ``bucket_capacity``— KV capacities: power-of-two doubling from a
                       ``floor``, bounded by a hard ``limit``.
* ``blocks_for``     — paged KV: blocks needed to hold ``n_tokens``
                       (ceil division; the page-table WIDTH is then
                       ``bucket_pow2(blocks_for(...))`` so the block
                       count stays data while the table shape is a
                       bucket).
"""
from __future__ import annotations


def bucket_len(n: int, bucket: int) -> int:
    """Round a sequence length up to the next multiple of ``bucket``."""
    return max(bucket, ((n + bucket - 1) // bucket) * bucket)


def bucket_pow2(n: int) -> int:
    """Round a batch / pool / page-table width up to a power of two."""
    b = 1
    while b < n:
        b *= 2
    return b


def bucket_capacity(need: int, floor: int, limit: int, kind: str) -> int:
    """Power-of-two capacity bucket >= ``need``, starting at ``floor``,
    bounded by ``limit`` (raises ValueError past the bound)."""
    cap = min(floor, limit)
    while cap < need:
        cap *= 2
    if cap > limit:
        raise ValueError(
            f"{kind} needs cache capacity {cap} > max_cache_len "
            f"{limit}; raise max_cache_len")
    return cap


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV slots (>= 1: even an empty
    allocation owns one block so a page table is never width 0)."""
    return max(1, (n_tokens + block_size - 1) // block_size)
