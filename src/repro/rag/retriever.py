"""Subgraph retrievers: G-Retriever-style (PCST-lite) and GRAG-style (ego-nets).

Both follow the paper's App. A.2 configuration:
* G-Retriever: top-k nodes and top-k edges by query similarity (k=3,
  edge cost 0.5), connected into a subgraph (prize-collecting Steiner
  tree approximated by similarity-weighted BFS joins).
* GRAG: top-k 2-hop ego networks around the highest-scoring entities,
  pruned to the top-10 entities.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.subgraph import Subgraph
from repro.rag.text_encoder import TextEncoder
from repro.rag.textgraph import TextGraph


@dataclasses.dataclass
class RetrieverIndex:
    graph: TextGraph
    encoder: TextEncoder
    node_vecs: np.ndarray            # [N, dim]
    edge_vecs: np.ndarray            # [E, dim]

    @staticmethod
    def build(graph: TextGraph, encoder: TextEncoder) -> "RetrieverIndex":
        node_vecs = encoder.encode(graph.node_text)
        edge_vecs = encoder.encode([graph.edge_text(e) for e in graph.edges])
        return RetrieverIndex(graph, encoder, node_vecs, edge_vecs)


class GRetrieverRetriever:
    """Top-k node/edge retrieval + connectivity repair (PCST-lite)."""

    def __init__(self, index: RetrieverIndex, top_k: int = 3,
                 edge_cost: float = 0.5):
        self.index = index
        self.top_k = top_k
        self.edge_cost = edge_cost

    def retrieve(self, query: str) -> Subgraph:
        g = self.index.graph
        qv = self.index.encoder.encode_one(query)
        node_scores = self.index.node_vecs @ qv
        edge_scores = self.index.edge_vecs @ qv

        top_nodes = np.argsort(-node_scores)[: self.top_k].tolist()
        top_edge_idx = np.argsort(-edge_scores)[: self.top_k]
        edges = [g.edges[i] for i in top_edge_idx
                 if edge_scores[i] > self.edge_cost * max(1e-9, edge_scores.max())]
        if not edges:                       # always keep the best edge
            edges = [g.edges[int(top_edge_idx[0])]]

        nodes = set(top_nodes)
        for s, _, d in edges:
            nodes.update((s, d))
        # connectivity repair: join prize nodes to the best edge's endpoints
        anchor = edges[0][0]
        extra = []
        for n in top_nodes:
            if n != anchor:
                extra.extend(g.bfs_path(anchor, n))
        all_edges = list(edges) + extra
        for s, _, d in extra:
            nodes.update((s, d))
        return Subgraph.from_lists(nodes, all_edges)


class GRAGRetriever:
    """Top-k 2-hop ego networks pruned to the top entities."""

    def __init__(self, index: RetrieverIndex, top_k: int = 3, hops: int = 2,
                 top_entities: int = 10):
        self.index = index
        self.top_k = top_k
        self.hops = hops
        self.top_entities = top_entities

    def retrieve(self, query: str) -> Subgraph:
        g = self.index.graph
        qv = self.index.encoder.encode_one(query)
        node_scores = self.index.node_vecs @ qv
        centers = np.argsort(-node_scores)[: self.top_k].tolist()
        whitelist = set(np.argsort(-node_scores)[: self.top_entities].tolist())
        whitelist.update(centers)
        sub = None
        for c in centers:
            ego = g.ego_subgraph(int(c), self.hops, node_whitelist=whitelist)
            sub = ego if sub is None else sub.union(ego)
        return sub if sub is not None else Subgraph.from_lists(centers, [])
