"""End-to-end graph-based RAG pipeline with optional SubGCache.

Three serving modes over the same retriever, GNN, and engine:

* ``run_baseline``  — per-query processing (paper's G-Retriever / GRAG
  baseline): every query prefills its own full prompt.
* ``run_subgcache`` — the paper's OFFLINE method: all queries present up
  front, one dendrogram cut (``plan_batch``), clusters served one at a
  time against a single live ``PrefixState``.
* ``serve_stream``  — ONLINE serving (DESIGN.md §7/§9): queries arrive
  on a timeline, each is assigned to a cluster incrementally
  (``OnlineClusterAssigner``), and representative prefix states live
  in a byte-budgeted ``PrefixPool``.  The default loop is CONTINUOUS
  in-flight batching (``serving/continuous.py``): arrivals admit into
  free slots between fixed-size decode chunks and rows retire the
  moment they emit EOS.  ``mode="drain"`` keeps the PR 3 loop —
  slot-limited micro-batches served to full completion — as the
  token-identical A/B oracle.  TTFT per query includes the
  arrival-queue wait.

Both SubGCache modes take a ``tree_levels`` knob (DESIGN.md §10): cut
the clustering dendrogram at several levels and serve each leaf
cluster against a root→leaf prefix CHAIN — ancestor segments hold the
content sibling clusters share, stored and prefilled once.
``tree_levels=1`` (default) is the flat single-cut path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.core.clustering import Dendrogram
from repro.core.embedding import embed_subgraphs, subgraph_tensors
from repro.core.planner import (BatchPlan, PrefixTreePlan, plan_batch,
                                plan_prefix_tree)
from repro.core.subgraph import Subgraph, textualize, textualize_delta
from repro.data.scenegraph import QAItem
from repro.data.tokenizer import Tokenizer
from repro.gnn.projector import apply_projector
from repro.rag.retriever import RetrieverIndex
from repro.serving.engine import ServingEngine
from repro.serving.metrics import QueryRecord, RunSummary

PREFIX_HEADER = "graph :"
QUESTION_HEADER = "question :"
ANSWER_HEADER = "answer :"


@dataclasses.dataclass
class GraphRAGPipeline:
    """Composition root: retriever + GNN encoder + serving engine +
    tokenizer, with the three serving modes as methods (see module
    docstring).  ``gnn_params``/``gnn_apply`` drive both the clustering
    embeddings and (with ``proj_params``) the soft graph prompt;
    without them clustering falls back to pooled text vectors."""
    index: RetrieverIndex
    retriever: object                   # GRetrieverRetriever | GRAGRetriever
    engine: ServingEngine
    tokenizer: Tokenizer
    gnn_params: Optional[dict] = None
    gnn_apply: Optional[Callable] = None
    proj_params: Optional[dict] = None
    use_soft_prompt: bool = True

    # ------------------------------------------------------------------
    def prefix_text(self, sg: Subgraph) -> str:
        """The cached prompt prefix: textualized (representative)
        subgraph.  Order-normalized so equal subgraphs give the
        identical string (the cached unit must be exact)."""
        return f"{PREFIX_HEADER}\n{textualize(sg, self.index.graph.node_text)}"

    def suffix_text(self, question: str) -> str:
        """The per-member prompt suffix appended after the prefix."""
        return f"{QUESTION_HEADER} {question} {ANSWER_HEADER}"

    def soft_prompt(self, sg: Subgraph) -> Optional[np.ndarray]:
        """[n_soft, D] GNN soft-prompt embeddings for ``sg`` (or None
        when soft prompting is disabled / no projector is loaded)."""
        if not (self.use_soft_prompt and self.proj_params is not None):
            return None
        x, snd, rcv, ef = subgraph_tensors(self.index, sg)
        h = self.gnn_apply(self.gnn_params, x, snd, rcv, ef)
        import jax.numpy as jnp
        pooled = jnp.mean(h, axis=0)
        return np.asarray(apply_projector(self.proj_params, pooled))

    def _check(self, generated: str, answer: str) -> bool:
        return answer.lower().strip() in generated.lower()

    # ------------------------------------------------------------------
    def retrieve_all(self, items: Sequence[QAItem]):
        """Retrieve one subgraph per query; returns (subgraphs,
        per-query retrieval seconds)."""
        subgraphs, times = [], []
        for it in items:
            t0 = time.perf_counter()
            sg = self.retriever.retrieve(it.question)
            times.append(time.perf_counter() - t0)
            subgraphs.append(sg)
        return subgraphs, times

    # ------------------------------------------------------------------
    def run_baseline(self, items: Sequence[QAItem]) -> tuple:
        """Per-query processing (paper's G-Retriever / GRAG baseline)."""
        subgraphs, ret_times = self.retrieve_all(items)
        records = []
        for it, sg, rt in zip(items, subgraphs, ret_times):
            t0 = time.perf_counter()
            soft = self.soft_prompt(sg)
            prompt = self.prefix_text(sg) + " " + self.suffix_text(it.question)
            toks = self.tokenizer.encode(prompt, bos=True)
            t_build = time.perf_counter() - t0
            out, t = self.engine.generate(toks, soft)
            text = self.tokenizer.decode(out)
            # soft-prompt embeddings are consumed like any other prompt
            # position: count them, or soft-prompt runs under-report
            # every prompt (and the prefill-savings denominators)
            n_soft = 0 if soft is None else soft.shape[0]
            records.append(QueryRecord(
                query=it.question, answer=it.answer, generated=text,
                correct=self._check(text, it.answer), retrieval_s=rt,
                prompt_build_s=t_build, prefill_s=t["prefill_s"],
                decode_s=t["decode_s"],
                prompt_tokens=len(toks) + n_soft))
        summary = RunSummary.from_records("baseline", records)
        return records, summary

    # ------------------------------------------------------------------
    def embed_for_clustering(self, subgraphs: Sequence[Subgraph]) -> np.ndarray:
        """[m, dim] clustering embeddings: the pretrained GNN when
        available (paper §3.2), else text-space pooled node vectors."""
        if self.gnn_params is not None:
            return embed_subgraphs(self.index, subgraphs, self.gnn_params,
                                   self.gnn_apply)
        return np.stack([
            np.mean(self.index.node_vecs[sorted(sg.nodes)], axis=0)
            for sg in subgraphs])

    def run_subgcache(self, items: Sequence[QAItem], num_clusters: int,
                      linkage: str = "ward", tree_levels: int = 1,
                      dendrogram: Optional[Dendrogram] = None,
                      compose: bool = False,
                      recompute_frac: float = 0.0) -> tuple:
        """Cluster-wise prefix-cache processing (the paper's method).

        ``tree_levels`` (DESIGN.md §10): cut the dendrogram at
        ``tree_levels`` levels and serve each leaf cluster against a
        root→leaf prefix CHAIN — shared ancestor segments prefilled
        once per ANCESTOR instead of once per cluster.  ``1`` (default)
        is the flat single-cut path, token-identical to the
        pre-refactor behavior.  Tree mode needs the cascade backends;
        stateful / cross-attention engines transparently serve flat.

        ``dendrogram``: pass a precomputed ``build_dendrogram`` result
        to make the clustering step a cheap cut replay (the fig3 sweep
        computes the merge tree once and cuts it per point).

        ``compose=True`` (paged backends; DESIGN.md §14): serve every
        leaf cluster through position-independent segment COMPOSITION
        instead of literal-prefix chains — segments are cached
        content-addressed (keyed by their delta token text), so a
        cluster whose prompt contains a segment some OTHER cluster
        already prefilled splices the cached copy at its own offset via
        read-time re-rotation, with ``recompute_frac`` of each spliced
        segment's leading tokens recomputed fresh (0.0 = pure splice,
        1.0 = dense-equivalent recompute — the quality-vs-TTFT dial,
        EXPERIMENTS.md).
        """
        if compose:
            return self._run_subgcache_compose(items, num_clusters, linkage,
                                               tree_levels, dendrogram,
                                               recompute_frac)
        if tree_levels > 1 and self.engine.use_split_prefix:
            return self._run_subgcache_tree(items, num_clusters, linkage,
                                            tree_levels, dendrogram)
        subgraphs, ret_times = self.retrieve_all(items)

        t0 = time.perf_counter()
        emb = self.embed_for_clustering(subgraphs)
        plan = plan_batch(subgraphs, emb, num_clusters, linkage,
                          dendrogram=dendrogram)
        cluster_time = (time.perf_counter() - t0
                        + plan.cluster_processing_time_s)
        share = cluster_time / max(1, len(items))

        # the engine records cluster/member token accounting into its
        # cache manager as it serves; start a fresh window for this run
        stats = self.engine.cache_mgr.reset_stats()
        records: List[QueryRecord] = [None] * len(items)  # type: ignore
        for cp in plan.clusters:
            t0 = time.perf_counter()
            rep = cp.representative
            soft = self.soft_prompt(rep)
            prefix_tokens = self.tokenizer.encode(self.prefix_text(rep),
                                                  bos=True)
            t_build_prefix = time.perf_counter() - t0

            state, t_prefix = self.engine.prefill_prefix(prefix_tokens, soft)
            n = len(cp.member_indices)

            suffixes, builds = [], []
            for qi in cp.member_indices:
                t1 = time.perf_counter()
                suffixes.append(
                    self.tokenizer.encode(self.suffix_text(items[qi].question)))
                builds.append(time.perf_counter() - t1)

            with self.engine.cache_mgr.cluster(state):
                outs, t = self.engine.generate_with_prefix(state, suffixes)

            for k, qi in enumerate(cp.member_indices):
                it = items[qi]
                text = self.tokenizer.decode(outs[k])
                # state.prefix_len counts the soft-prompt embeds the
                # prefix prefill consumed (PrefixState.n_soft), which
                # len(prefix_tokens) does not
                member_prompt = state.prefix_len + len(suffixes[k])
                # per-member shares come from the engine: the stateful
                # fallback serves equal-length SUB-batches, so dividing
                # the summed prefill/decode time by the cluster size n
                # would misattribute cost across sub-batches
                records[qi] = QueryRecord(
                    query=it.question, answer=it.answer, generated=text,
                    correct=self._check(text, it.answer),
                    retrieval_s=ret_times[qi], cluster_share_s=share,
                    prompt_build_s=builds[k] + t_build_prefix / n,
                    prefix_share_s=t_prefix / n,
                    prefill_s=t["prefill_share"][k],
                    decode_s=t["decode_share"][k],
                    prompt_tokens=member_prompt,
                    cached_tokens=state.prefix_len)
        summary = RunSummary.from_records(
            f"subgcache(c={num_clusters},{linkage})", records,
            cluster_processing_s=cluster_time,
            prefill_savings=stats.prefill_savings)
        return records, summary, plan, stats

    # ------------------------------------------------------------------
    def _run_subgcache_tree(self, items: Sequence[QAItem],
                            num_clusters: int, linkage: str,
                            tree_levels: int,
                            dendrogram: Optional[Dendrogram]) -> tuple:
        """Offline serving over a prefix tree (DESIGN.md §10): ancestor
        segments are prefilled ONCE and kept live while every
        descendant leaf is served against its root→leaf chain; each
        leaf's own extension is released after its cluster (the flat
        path's one-live-prefix bound, per segment level).  Ancestor
        prefill cost and text build are amortized over the members
        UNDER the ancestor — the same uniform-share rule the flat path
        applies per cluster."""
        subgraphs, ret_times = self.retrieve_all(items)

        t0 = time.perf_counter()
        emb = self.embed_for_clustering(subgraphs)
        plan = plan_prefix_tree(subgraphs, emb, num_clusters,
                                tree_levels=tree_levels, linkage=linkage,
                                dendrogram=dendrogram)
        cluster_time = (time.perf_counter() - t0
                        + plan.cluster_processing_time_s)
        share = cluster_time / max(1, len(items))

        members_under = {n.node_id: 0 for n in plan.nodes}
        for leaf in plan.leaves:
            k = len(plan.nodes[leaf].member_indices)
            for nid in plan.path(leaf):
                members_under[nid] += k

        stats = self.engine.cache_mgr.reset_stats()
        seg_states: dict = {}        # node_id -> (state, prefill_s, build_s)
        records: List[QueryRecord] = [None] * len(items)  # type: ignore
        try:
            for leaf in plan.leaves:
                node = plan.nodes[leaf]
                path = plan.path(leaf)
                parent_state = None
                prefix_share = build_share = 0.0
                for depth, nid in enumerate(path):
                    hit = nid in seg_states
                    if not hit:
                        t1 = time.perf_counter()
                        content = plan.nodes[nid].content
                        base = (plan.nodes[path[depth - 1]].content
                                if depth else None)
                        payload = self._segment_payload(content, base)
                        toks, soft = (payload if isinstance(payload, tuple)
                                      else (payload, None))
                        t_build = time.perf_counter() - t1
                        if parent_state is None:
                            st, dt = self.engine.prefill_prefix(toks, soft)
                        else:
                            st, dt = self.engine.prefill_prefix_extension(
                                parent_state, toks)
                        seg_states[nid] = (st, dt, t_build)
                    st, dt, t_build = seg_states[nid]
                    stats.record_tree_segment(depth, st.segment_len,
                                              hit=hit, leaf=(nid == leaf))
                    prefix_share += dt / members_under[nid]
                    build_share += t_build / members_under[nid]
                    parent_state = st
                state = parent_state

                suffixes, builds = [], []
                for qi in node.member_indices:
                    t1 = time.perf_counter()
                    suffixes.append(self.tokenizer.encode(
                        self.suffix_text(items[qi].question)))
                    builds.append(time.perf_counter() - t1)

                del seg_states[leaf]     # the ctx below releases the leaf
                with self.engine.cache_mgr.cluster(state):
                    outs, t = self.engine.generate_with_prefix(state,
                                                               suffixes)

                for k, qi in enumerate(node.member_indices):
                    it = items[qi]
                    text = self.tokenizer.decode(outs[k])
                    records[qi] = QueryRecord(
                        query=it.question, answer=it.answer, generated=text,
                        correct=self._check(text, it.answer),
                        retrieval_s=ret_times[qi], cluster_share_s=share,
                        prompt_build_s=builds[k] + build_share,
                        prefix_share_s=prefix_share,
                        prefill_s=t["prefill_share"][k],
                        decode_s=t["decode_share"][k],
                        prompt_tokens=state.prefix_len + len(suffixes[k]),
                        cached_tokens=state.prefix_len)
        finally:
            for st, _, _ in seg_states.values():
                st.release()             # ancestors freed after the batch
        summary = RunSummary.from_records(
            f"subgcache(c={num_clusters},{linkage},tree{tree_levels})",
            records, cluster_processing_s=cluster_time,
            prefill_savings=stats.prefill_savings)
        return records, summary, plan, stats

    # ------------------------------------------------------------------
    def _run_subgcache_compose(self, items: Sequence[QAItem],
                               num_clusters: int, linkage: str,
                               tree_levels: int,
                               dendrogram: Optional[Dendrogram],
                               recompute_frac: float) -> tuple:
        """Offline serving via segment composition (DESIGN.md §14).

        Segments are cached CONTENT-addressed: the registry maps a
        segment's delta token text (``textualize_delta`` is
        order-normalized, so equal content sets give byte-identical
        text) to its cached ``PrefixState``.  Per leaf cluster:

        * the cold LEADING run of its path is prefilled as a chain and
          registered — a segment's cached KV encodes attention over its
          left context, so only contiguous-from-root segments are
          coherent enough to cache;
        * a registry hit ANYWHERE in the path splices the cached copy
          at this prompt's offset (read-time re-rotation), even when it
          was prefilled under a different cluster at a different
          position — the cross-cluster reuse literal-prefix chains
          never expressed;
        * segments behind a splice or gap are served as fresh GAP spans
          (recomputed per serve, not cached).

        Exact-offset hits (shared dendrogram ancestors) splice with a
        zero delta and stay token-identical to the chain path;
        re-based splices are approximate — ``recompute_frac`` and the
        benchmark's greedy-match gate govern that trade."""
        from repro.core.planner import plan_composition
        from repro.serving.engine import Request
        assert self.engine.use_paged, \
            "segment composition rides the paged backend (DESIGN.md §14)"
        subgraphs, ret_times = self.retrieve_all(items)

        t0 = time.perf_counter()
        emb = self.embed_for_clustering(subgraphs)
        plan = plan_prefix_tree(subgraphs, emb, num_clusters,
                                tree_levels=tree_levels, linkage=linkage,
                                dendrogram=dendrogram)
        cluster_time = (time.perf_counter() - t0
                        + plan.cluster_processing_time_s)
        share = cluster_time / max(1, len(items))

        stats = self.engine.cache_mgr.reset_stats()
        reg: dict = {}           # segment token content -> PrefixState
        owned: List = []         # registry-owned states (released below)
        records: List[QueryRecord] = [None] * len(items)  # type: ignore
        try:
            for leaf in plan.leaves:
                node = plan.nodes[leaf]
                path = plan.path(leaf)
                t1 = time.perf_counter()
                seg_toks: List[List[int]] = []
                for depth, nid in enumerate(path):
                    content = plan.nodes[nid].content
                    base = (plan.nodes[path[depth - 1]].content
                            if depth else None)
                    payload = self._segment_payload(content, base)
                    toks, soft = (payload if isinstance(payload, tuple)
                                  else (payload, None))
                    assert soft is None, \
                        "compose mode serves token segments — disable " \
                        "the soft graph prompt (use_soft_prompt=False)"
                    seg_toks.append(list(toks))
                t_build_prefix = time.perf_counter() - t1

                # cache + register the cold leading run of the path
                t1 = time.perf_counter()
                parent, extendable, off = None, True, 0
                for toks in seg_toks:
                    key = tuple(toks)
                    hit = reg.get(key)
                    if hit is not None:
                        # extension may continue only through an
                        # exact-offset hit (a shared ancestor): its
                        # chain IS this path's prefix
                        extendable = extendable and hit.base_pos == off
                        parent = hit if extendable else None
                    elif extendable:
                        if parent is None:
                            st, _ = self.engine.prefill_prefix(
                                toks, _record=False)
                        else:
                            st, _ = self.engine.prefill_prefix_extension(
                                parent, toks, _record=False)
                        reg[key] = st
                        owned.append(st)
                        parent = st
                    else:
                        parent = None            # gap: not cacheable
                    off += len(toks)
                t_prefix = time.perf_counter() - t1

                comp = plan_composition(seg_toks, reg.get,
                                        recompute_frac=recompute_frac)
                assert comp is not None     # the leading run registered

                n = len(node.member_indices)
                suffixes, builds = [], []
                for qi in node.member_indices:
                    t1 = time.perf_counter()
                    suffixes.append(self.tokenizer.encode(
                        self.suffix_text(items[qi].question)))
                    builds.append(time.perf_counter() - t1)

                outs, t = self.engine.serve(
                    [Request(suffix_tokens=s, composition=comp)
                     for s in suffixes])

                for k, qi in enumerate(node.member_indices):
                    it = items[qi]
                    text = self.tokenizer.decode(outs[k])
                    records[qi] = QueryRecord(
                        query=it.question, answer=it.answer,
                        generated=text,
                        correct=self._check(text, it.answer),
                        retrieval_s=ret_times[qi], cluster_share_s=share,
                        prompt_build_s=builds[k] + t_build_prefix / n,
                        prefix_share_s=t_prefix / n,
                        prefill_s=t["prefill_share"][k],
                        decode_s=t["decode_share"][k],
                        prompt_tokens=comp.total_len + len(suffixes[k]),
                        cached_tokens=comp.spliced_tokens())
        finally:
            for st in owned:
                st.release()
        summary = RunSummary.from_records(
            f"subgcache-compose(c={num_clusters},{linkage},"
            f"tree{tree_levels},frac={recompute_frac})", records,
            cluster_processing_s=cluster_time,
            prefill_savings=stats.prefill_savings)
        return records, summary, plan, stats

    # ------------------------------------------------------------------
    def _prefix_payload(self, sg: Subgraph):
        """(prefix tokens, soft-prompt embeds or None) for a cluster
        representative — the closure ``OnlineScheduler`` prefills with."""
        toks = self.tokenizer.encode(self.prefix_text(sg), bos=True)
        return toks, self.soft_prompt(sg)

    def _segment_payload(self, content: Subgraph,
                         base: Optional[Subgraph] = None):
        """Token ids of ONE prefix-chain segment (DESIGN.md §10):
        ``content``'s delta over ``base``.  ``base=None`` is the root
        segment — full textualization with the prefix header, BOS, and
        the soft graph prompt (consumed once, at the path's start, so
        every descendant chain shares it byte-for-byte); deeper
        segments carry only their delta text."""
        if base is None:
            return self._prefix_payload(content)
        return self.tokenizer.encode(
            textualize_delta(content, self.index.graph.node_text, base))

    def serve_stream(self, items: Sequence[QAItem],
                     arrivals: Sequence[float], *,
                     max_batch: int = 8,
                     pool_budget_bytes: int = 1 << 30,
                     threshold: float = float("inf"),
                     max_clusters: Optional[int] = None,
                     mode: str = "continuous", chunk: int = 4,
                     max_suffix_len: Optional[int] = None,
                     tree_levels: int = 1,
                     tree_clusters: Optional[int] = None,
                     host_tier_bytes: Optional[int] = None,
                     scheduler=None, replicas: int = 1,
                     compose_frac: Optional[float] = None) -> tuple:
        """Online serving of a streaming query trace (DESIGN.md §7/§9).

        ``items[i]`` arrives at ``arrivals[i]`` seconds (any order).
        Two serving loops share the assigner + pool + engine substrate:

        * ``mode="continuous"`` (default; paged backends) — an event
          loop over ``ContinuousEngine``: arrivals admit into free
          slots of a persistent in-flight batch between fixed
          ``chunk``-step decode chunks, rows retire (and free their
          suffix blocks) the moment they emit EOS, and per-row
          prefill/decode attribution is exact.  No request ever waits
          for another request's decode to finish.
        * ``mode="drain"`` — the PR 3 drain-serve loop, kept as the A/B
          oracle: the queue is drained into micro-batches of at most
          ``max_batch`` queries and each batch is served to FULL
          completion before the queue is consulted again.  Token
          streams are identical between the modes (the continuous path
          only reschedules work, never changes math); dense/stateful
          engines always take this path.

        The virtual clock jumps to the next arrival when idle and
        advances by the measured wall time of each admission / decode
        chunk / drained batch, so ``queue_wait_s`` reflects real
        service times.  Pass ``scheduler`` (a previous call's return
        value) to keep the cluster population and prefix pool warm
        across traces.  Returns ``(records, summary, scheduler)``; pool
        hit/miss/eviction counters live in ``scheduler.pool.stats``.

        ``tree_levels`` > 1 (DESIGN.md §10; split-cascade engines)
        seeds the assigner from a multi-level prefix-tree plan over the
        trace's own retrievals (the warm-start bootstrap ``from_plan``
        already models, cut at ``tree_clusters`` leaves): cluster
        prefixes become root→leaf chains whose shared ancestor segments
        are pooled ONCE and pinned per in-flight row.  ``1`` (default)
        is the flat path, token-identical to the pre-refactor behavior.

        ``host_tier_bytes`` (paged backends; DESIGN.md §12) attaches a
        host-memory tier of that byte budget under the prefix pool:
        evictions demote segment blocks to pinned host buffers instead
        of discarding them, later hits promote them back through an
        async ``device_put``, and queued-but-not-admitted arrivals are
        speculatively prefetched (``OnlineScheduler.prefetch``) so the
        transfer overlaps their queue wait.  Token streams are
        unchanged — a promoted segment serves bit-for-bit the blocks it
        was demoted from.

        ``replicas`` > 1 (paged backends; DESIGN.md §13) serves the
        trace through a ``ReplicaRouter`` over that many engine
        replicas — each with a PRIVATE block arena, prefix pool, and
        host tier — under cluster-affinity placement with least-loaded
        spawns and hot-replica rebalancing.  One shared assigner is
        consulted in global arrival order, so the token streams stay
        identical to ``replicas=1``; returns ``(records, summary,
        router)`` (the router in the scheduler slot).

        ``compose_frac`` (paged backends; DESIGN.md §14) turns on
        position-independent segment composition: before materializing
        a cluster's chain the scheduler consults its content-addressed
        segment registry and, when the chain can be assembled from
        resident segments with at least one re-based splice, serves the
        row from a ``SegmentComposition`` instead of prefilling — only
        gap spans and a boundary recompute window of that fraction per
        segment are recomputed.  ``1.0`` recomputes every spliced token
        (token-identical to the chain path); ``None`` (default)
        disables composition entirely.
        """
        from repro.core.prefix_pool import PrefixPool
        from repro.serving.scheduler import ArrivalQueue, OnlineScheduler
        assert len(items) == len(arrivals)
        assert mode in ("continuous", "drain"), mode
        if replicas > 1:
            assert self.engine.use_paged, \
                "replica serving requires the paged backend"
            # ``scheduler`` doubles as the warm-router slot here: pass a
            # previous replica call's returned router to replay warm
            return self._serve_stream_replicas(
                items, arrivals, replicas=replicas, max_batch=max_batch,
                pool_budget_bytes=pool_budget_bytes, threshold=threshold,
                max_clusters=max_clusters, mode=mode, chunk=chunk,
                max_suffix_len=max_suffix_len, tree_levels=tree_levels,
                tree_clusters=tree_clusters,
                host_tier_bytes=host_tier_bytes, router=scheduler)
        stats = self.engine.cache_mgr.reset_stats()
        if scheduler is None:
            assigner = self._make_assigner(items, threshold, max_clusters,
                                           tree_levels, tree_clusters)
            # OnlineScheduler owns the stats wiring: it points the
            # pool's counters at the engine's (just-reset) window
            scheduler = OnlineScheduler(
                self.engine, assigner, PrefixPool(pool_budget_bytes),
                self._prefix_payload,
                segment_tokens_fn=self._segment_payload)
        else:
            scheduler.pool.stats = stats    # fresh accounting window
            if scheduler.pool.tier is not None:
                scheduler.pool.tier.stats = stats
        scheduler.compose_frac = compose_frac
        if compose_frac is not None:
            assert self.engine.use_paged, \
                "segment composition requires the paged backend"
        if host_tier_bytes is not None and scheduler.pool.tier is None \
                and getattr(self.engine, "block_pool", None) is not None:
            from repro.core.tiered import HostTier
            scheduler.pool.attach_host_tier(HostTier(host_tier_bytes))

        if mode == "continuous" and self.engine.use_paged:
            return self._serve_stream_continuous(
                items, arrivals, scheduler, max_batch, chunk,
                max_suffix_len)

        queue = ArrivalQueue()
        for i, t_arr in enumerate(arrivals):
            queue.push(t_arr, i)
        records: List[QueryRecord] = [None] * len(items)  # type: ignore
        clock, pf_memo = 0.0, {}
        while len(queue):
            now = max(clock, queue.next_arrival())
            batch = queue.drain(now, max_batch)
            idxs = [a.payload for a in batch]
            t_batch0 = time.perf_counter()
            subgraphs, ret_times = self.retrieve_all(
                [items[i] for i in idxs])
            t0 = time.perf_counter()
            emb = self.embed_for_clustering(subgraphs)
            suffixes, builds = [], []
            for i in idxs:
                t1 = time.perf_counter()
                suffixes.append(self.tokenizer.encode(
                    self.suffix_text(items[i].question)))
                builds.append(time.perf_counter() - t1)
            served = scheduler.serve_batch(list(emb), subgraphs, suffixes)
            t_serve = time.perf_counter() - t0
            # embedding/assignment/pool overhead not already attributed
            # to a query by the engine, spread uniformly over the batch
            engine_s = sum(s.prefix_share_s + s.prefill_s + s.decode_s
                           for s in served)
            share = max(0.0, t_serve - engine_s - sum(builds)) / len(batch)
            for k, (a, i, sq) in enumerate(zip(batch, idxs, served)):
                it = items[i]
                text = self.tokenizer.decode(sq.tokens)
                records[i] = QueryRecord(
                    query=it.question, answer=it.answer, generated=text,
                    correct=self._check(text, it.answer),
                    retrieval_s=ret_times[k], queue_wait_s=now - a.time_s,
                    cluster_share_s=share, prompt_build_s=builds[k],
                    prefix_share_s=sq.prefix_share_s,
                    prefill_s=sq.prefill_s, decode_s=sq.decode_s,
                    # the monolithic decode burns the whole budget for
                    # every row — that IS the drain-serve wasted-decode
                    # cost the continuous loop retires away
                    decode_steps=self.engine.max_new_tokens - 1,
                    prompt_tokens=sq.prefix_len + len(suffixes[k]),
                    cached_tokens=sq.prefix_len if sq.pool_hit else 0)
            clock = now + (time.perf_counter() - t_batch0)
            # speculate for the overflow this batch left queued: start
            # their clusters' host→device promotions now, so the async
            # transfers overlap the queue wait, not the next batch
            clock += self._prefetch_queued(scheduler, queue, items,
                                           clock, max_batch, pf_memo)
        summary = RunSummary.from_records(
            f"online(b={max_batch})", records,
            prefill_savings=stats.prefill_savings)
        return records, summary, scheduler

    def _make_assigner(self, items, threshold, max_clusters,
                       tree_levels: int, tree_clusters):
        """The online cluster assigner for a trace over ``items`` —
        flat, or seeded from a multi-level prefix-tree plan over the
        trace's own retrievals (untimed bootstrap pass — the flat
        ``from_plan`` warm start with a deeper cut)."""
        from repro.serving.scheduler import OnlineClusterAssigner
        if tree_levels > 1 and self.engine.use_split_prefix:
            subgraphs, _ = self.retrieve_all(items)
            emb = self.embed_for_clustering(subgraphs)
            k = tree_clusters if tree_clusters is not None else \
                (max_clusters if max_clusters is not None else 8)
            plan = plan_prefix_tree(subgraphs, emb, k,
                                    tree_levels=tree_levels)
            return OnlineClusterAssigner.from_tree_plan(
                plan, emb, threshold=threshold, max_clusters=max_clusters)
        return OnlineClusterAssigner(threshold=threshold,
                                     max_clusters=max_clusters)

    def _prefetch_queued(self, scheduler, queue, items, now: float,
                         limit: int, memo: dict) -> float:
        """Speculative host→device prefetch for arrivals that are
        queued but not yet admitted (DESIGN.md §12): probe each one
        against the live centroids and start promoting its cluster's
        host-resident chain segments, so the async transfer overlaps
        the remaining queue wait.  Per-item embeddings are memoized —
        one probe per query however many iterations it stays queued.
        Returns the measured host-side seconds (callers keep it on the
        clock: speculation is work, not free time)."""
        tier = scheduler.pool.tier
        if tier is None or not len(tier) or not len(queue) \
                or not scheduler.assigner.clusters:
            return 0.0
        t0 = time.perf_counter()
        embs = []
        for a in queue.peek(now, limit):
            i = a.payload
            if i not in memo:
                sgs, _ = self.retrieve_all([items[i]])
                memo[i] = self.embed_for_clustering(sgs)[0]
            embs.append(memo[i])
        if embs:
            scheduler.prefetch(embs)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def warmup_stream(self, items: Sequence[QAItem], *,
                      max_batch: int = 8, chunk: int = 4,
                      prefix_lens: Optional[Sequence[int]] = None,
                      max_suffix_len: Optional[int] = None) -> None:
        """Pre-compile the continuous-serving shape grid for a trace
        over ``items`` (no-op on dense/stateful engines).  Suffix
        capacity is sized exactly as ``serve_stream`` will size it;
        ``prefix_lens`` (one per representative length the trace can
        serve) skips the per-item retrieval pass when the caller
        already knows them.  Untimed and excluded from CacheStats."""
        if not self.engine.use_paged:
            return
        from repro.serving.continuous import ContinuousEngine
        if prefix_lens is None:
            prefix_lens = sorted({len(self.tokenizer.encode(
                self.prefix_text(self.retriever.retrieve(it.question)),
                bos=True)) for it in items})
        max_sfx = max_suffix_len if max_suffix_len is not None else max(
            len(self.tokenizer.encode(self.suffix_text(it.question)))
            for it in items)
        cont = ContinuousEngine(self.engine, max_slots=max_batch,
                                chunk=chunk, max_suffix_len=max_sfx)
        cont.warmup(prefix_lens)

    # ------------------------------------------------------------------
    def _serve_stream_continuous(self, items: Sequence[QAItem],
                                 arrivals: Sequence[float], scheduler,
                                 max_batch: int, chunk: int,
                                 max_suffix_len: Optional[int] = None
                                 ) -> tuple:
        """Event loop over a persistent in-flight batch (DESIGN.md §9).

        Each iteration: (1) admit everything that has arrived by the
        clock into free slots (retrieve → embed → assign → materialize
        pinned prefixes → one batched suffix prefill), (2) run ONE
        ``chunk``-step decode, (3) collect retirements.  The clock
        advances by the measured wall time of each iteration, so a
        query's ``queue_wait_s`` ends the moment it is admitted — not
        when the previous batch finishes decoding.
        """
        from repro.serving.continuous import ContinuousEngine
        from repro.serving.scheduler import ArrivalQueue
        stats = self.engine.cache_mgr.stats
        # suffix capacity is a compiled shape: size it to the trace —
        # callers replaying a trace (benchmarks, warm schedulers) pass
        # ``max_suffix_len`` to skip re-tokenizing every suffix per call
        # (admission still encodes each suffix once, on the clock)
        max_sfx = max_suffix_len if max_suffix_len is not None else max(
            len(self.tokenizer.encode(self.suffix_text(it.question)))
            for it in items)
        cont = ContinuousEngine(self.engine, max_slots=max_batch,
                                chunk=chunk, max_suffix_len=max_sfx)
        queue = ArrivalQueue()
        for i, t_arr in enumerate(arrivals):
            queue.push(t_arr, i)
        records: List[QueryRecord] = [None] * len(items)  # type: ignore
        clock, pf_memo = 0.0, {}
        while len(queue) or cont.in_flight:
            if cont.in_flight == 0 and len(queue):
                clock = max(clock, queue.next_arrival())
            batch = queue.drain(clock, cont.free_slots)
            t_iter0 = time.perf_counter()
            if batch:
                idxs = [a.payload for a in batch]
                subgraphs, ret_times = self.retrieve_all(
                    [items[i] for i in idxs])
                t0 = time.perf_counter()
                emb = self.embed_for_clustering(subgraphs)
                suffixes, builds = [], []
                for i in idxs:
                    t1 = time.perf_counter()
                    suffixes.append(self.tokenizer.encode(
                        self.suffix_text(items[i].question)))
                    builds.append(time.perf_counter() - t1)
                payloads = [
                    {"i": i, "wait": clock - a.time_s, "retrieval": rt,
                     "build": bd, "suffix_len": len(sfx)}
                    for a, i, rt, bd, sfx in zip(batch, idxs, ret_times,
                                                 builds, suffixes)]
                admitted, prefill_s = scheduler.serve_continuous(
                    cont, list(emb), subgraphs, suffixes, payloads,
                    now=clock)
                t_admit = time.perf_counter() - t0
                # embedding/assignment/pool overhead not attributed to a
                # query by the engine, spread uniformly over the group
                engine_s = prefill_s + sum(
                    aq.prefix_share_s for aq in admitted)
                share = max(0.0, t_admit - engine_s - sum(builds)) \
                    / len(batch)
                for aq in admitted:
                    aq.payload["share"] = share
            if cont.in_flight:
                cont.step()
            clock += time.perf_counter() - t_iter0
            # overflow still waiting for a slot: start its host→device
            # promotions so the transfers overlap the queue wait
            clock += self._prefetch_queued(scheduler, queue, items,
                                           clock, max_batch, pf_memo)
            for res in cont.pop_retired():
                aq = res.payload
                meta = aq.payload
                i = meta["i"]
                it = items[i]
                text = self.tokenizer.decode(res.tokens)
                records[i] = QueryRecord(
                    query=it.question, answer=it.answer, generated=text,
                    correct=self._check(text, it.answer),
                    retrieval_s=meta["retrieval"],
                    queue_wait_s=meta["wait"],
                    cluster_share_s=meta.get("share", 0.0),
                    prompt_build_s=meta["build"],
                    prefix_share_s=aq.prefix_share_s,
                    prefill_s=res.prefill_s,
                    decode_s=res.decode_s,          # exact, not t/n
                    decode_steps=res.decode_steps,
                    # prefix_len includes any soft-prompt embeds the
                    # prefill actually consumed (PrefixState.n_soft)
                    prompt_tokens=aq.prefix_len + meta["suffix_len"],
                    cached_tokens=aq.prefix_len if aq.pool_hit else 0)
        summary = RunSummary.from_records(
            f"continuous(b={max_batch},chunk={chunk})", records,
            prefill_savings=stats.prefill_savings)
        return records, summary, scheduler

    # ------------------------------------------------------------------
    def _serve_stream_replicas(self, items: Sequence[QAItem],
                               arrivals: Sequence[float], *,
                               replicas: int, max_batch: int,
                               pool_budget_bytes: int, threshold: float,
                               max_clusters: Optional[int], mode: str,
                               chunk: int,
                               max_suffix_len: Optional[int],
                               tree_levels: int,
                               tree_clusters: Optional[int],
                               host_tier_bytes: Optional[int],
                               router=None) -> tuple:
        """Serve one trace through a ``ReplicaRouter`` (DESIGN.md §13).

        Interleaved per-replica virtual clocks: each iteration picks
        the replica with the earliest actionable time — but only after
        every arrival due by that time has been ROUTED (retrieve →
        embed → one shared-assigner ``route`` per arrival, in global
        arrival order), since a just-routed arrival may hand an idle
        replica an earlier event.  The acting replica then admits from
        its private queue and runs one decode chunk (continuous) or
        drains one micro-batch to completion (drain), advancing its own
        clock by the measured wall time; the router rebalances between
        iterations.  Makespan = the slowest replica's clock — the
        number the scaling bench divides query count by.

        Pass a previous call's ``router`` to replay against warm
        engines/placements (its counters are reset; the cluster
        population and jit caches are the warmth)."""
        from repro.serving.continuous import ContinuousEngine
        from repro.serving.router import ReplicaRouter
        if router is None:
            assigner = self._make_assigner(items, threshold, max_clusters,
                                           tree_levels, tree_clusters)
            router = ReplicaRouter.build(
                self.engine, assigner, replicas,
                pool_budget_bytes=pool_budget_bytes,
                prefix_tokens_fn=self._prefix_payload,
                segment_tokens_fn=self._segment_payload,
                host_tier_bytes=host_tier_bytes)
        else:
            assert len(router.replicas) == replicas, \
                (len(router.replicas), replicas)
            router.reset_counters()
            for r in router.replicas:
                st = r.engine.cache_mgr.reset_stats()
                r.scheduler.pool.stats = st
                if r.scheduler.pool.tier is not None:
                    r.scheduler.pool.tier.stats = st
        conts = None
        if mode == "continuous":
            max_sfx = max_suffix_len if max_suffix_len is not None else \
                max(len(self.tokenizer.encode(
                    self.suffix_text(it.question))) for it in items)
            conts = [ContinuousEngine(r.engine, max_slots=max_batch,
                                      chunk=chunk, max_suffix_len=max_sfx)
                     for r in router.replicas]

        order = sorted(range(len(items)), key=lambda i: arrivals[i])
        ptr = 0
        records: List[QueryRecord] = [None] * len(items)  # type: ignore

        def route_due(now: float) -> None:
            """Advance the global routing frontier to ``now``: assign +
            place every not-yet-routed arrival with time <= now, in
            arrival order (the token-identity invariant)."""
            nonlocal ptr
            while ptr < len(order) and arrivals[order[ptr]] <= now:
                i = order[ptr]
                ptr += 1
                sgs, rts = self.retrieve_all([items[i]])
                emb = self.embed_for_clustering(sgs)[0]
                rt = router.route(emb, sgs[0])
                t1 = time.perf_counter()
                sfx = self.tokenizer.encode(
                    self.suffix_text(items[i].question))
                router.replicas[rt.replica].queue.push(arrivals[i], {
                    "i": i, "a": rt.assignment, "sg": sgs[0],
                    "emb": emb, "ret": rts[0], "sfx": sfx,
                    "build": time.perf_counter() - t1})

        def action_times():
            out = []
            for r in router.replicas:
                busy = conts[r.idx].in_flight if conts else 0
                if busy:
                    out.append((r.clock, r.idx))
                elif len(r.queue):
                    out.append((max(r.clock, r.queue.next_arrival()),
                                r.idx))
            return out

        while True:
            times = action_times()
            t_arr = arrivals[order[ptr]] if ptr < len(order) else None
            if not times:
                if t_arr is None:
                    break                      # drained everywhere
                route_due(t_arr)               # idle fleet: jump ahead
                continue
            t_act, idx = min(times)
            if t_arr is not None and t_arr <= t_act:
                # a pending arrival may hand an idle replica an event
                # EARLIER than t_act — route first, then re-evaluate
                route_due(t_act)
                continue
            r = router.replicas[idx]
            r.clock = max(r.clock, t_act)
            if conts is not None:
                self._replica_step_continuous(r, conts[idx], router,
                                              items, records)
            else:
                self._replica_step_drain(r, router, items, records,
                                         max_batch)
            router.maybe_rebalance()

        base = sum(r.stats.prefill_tokens_baseline
                   for r in router.replicas)
        cached = sum(r.stats.prefill_tokens_cached
                     for r in router.replicas)
        summary = RunSummary.from_records(
            f"replicas(n={replicas},{mode})", records,
            prefill_savings=base / cached if cached else 1.0)
        return records, summary, router

    def _replica_step_continuous(self, r, cont, router, items,
                                 records) -> None:
        """One continuous-mode iteration on replica ``r``: admit due
        arrivals into free slots, one ``chunk``-step decode, collect
        retirements (same accounting as the single-engine loop)."""
        batch = r.queue.drain(r.clock, cont.free_slots)
        t0 = time.perf_counter()
        if batch:
            metas = [a.payload for a in batch]
            payloads = [
                {"i": m["i"], "wait": r.clock - a.time_s,
                 "retrieval": m["ret"], "build": m["build"],
                 "suffix_len": len(m["sfx"])}
                for a, m in zip(batch, metas)]
            admitted, prefill_s = r.scheduler.serve_continuous(
                cont, [m["emb"] for m in metas],
                [m["sg"] for m in metas], [m["sfx"] for m in metas],
                payloads, now=r.clock,
                assignments=[m["a"] for m in metas])
            t_admit = time.perf_counter() - t0
            engine_s = prefill_s + sum(aq.prefix_share_s
                                       for aq in admitted)
            share = max(0.0, t_admit - engine_s) / len(batch)
            for aq in admitted:
                aq.payload["share"] = share
        if cont.in_flight:
            cont.step()
        r.clock += time.perf_counter() - t0
        for res in cont.pop_retired():
            aq = res.payload
            meta = aq.payload
            i = meta["i"]
            it = items[i]
            text = self.tokenizer.decode(res.tokens)
            records[i] = QueryRecord(
                query=it.question, answer=it.answer, generated=text,
                correct=self._check(text, it.answer),
                retrieval_s=meta["retrieval"],
                queue_wait_s=meta["wait"],
                cluster_share_s=meta.get("share", 0.0),
                prompt_build_s=meta["build"],
                prefix_share_s=aq.prefix_share_s,
                prefill_s=res.prefill_s, decode_s=res.decode_s,
                decode_steps=res.decode_steps,
                prompt_tokens=aq.prefix_len + meta["suffix_len"],
                cached_tokens=aq.prefix_len if aq.pool_hit else 0,
                replica=r.idx)
            router.retire(r.idx, aq.cluster_id)

    def _replica_step_drain(self, r, router, items, records,
                            max_batch: int) -> None:
        """One drain-mode iteration on replica ``r``: serve one
        micro-batch to full completion (the oracle loop's economics,
        replicated)."""
        batch = r.queue.drain(r.clock, max_batch)
        if not batch:
            return
        metas = [a.payload for a in batch]
        t0 = time.perf_counter()
        served = r.scheduler.serve_batch(
            [m["emb"] for m in metas], [m["sg"] for m in metas],
            [m["sfx"] for m in metas],
            assignments=[m["a"] for m in metas])
        t_serve = time.perf_counter() - t0
        engine_s = sum(s.prefix_share_s + s.prefill_s + s.decode_s
                       for s in served)
        share = max(0.0, t_serve - engine_s) / len(batch)
        for a, m, sq in zip(batch, metas, served):
            i = m["i"]
            it = items[i]
            text = self.tokenizer.decode(sq.tokens)
            records[i] = QueryRecord(
                query=it.question, answer=it.answer, generated=text,
                correct=self._check(text, it.answer),
                retrieval_s=m["ret"],
                queue_wait_s=r.clock - a.time_s,
                cluster_share_s=share, prompt_build_s=m["build"],
                prefix_share_s=sq.prefix_share_s,
                prefill_s=sq.prefill_s, decode_s=sq.decode_s,
                decode_steps=self.engine.max_new_tokens - 1,
                prompt_tokens=sq.prefix_len + len(m["sfx"]),
                cached_tokens=sq.prefix_len if sq.pool_hit else 0,
                replica=r.idx)
            router.retire(r.idx, sq.cluster_id)
        r.clock += t_serve
