"""End-to-end graph-based RAG pipeline with optional SubGCache.

Baseline mode reproduces G-Retriever / GRAG single-query processing;
SubGCache mode implements the paper's cluster -> representative subgraph
-> prefix-reuse loop on top of the same retriever, GNN, and engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.core.embedding import embed_subgraphs, subgraph_tensors
from repro.core.planner import BatchPlan, plan_batch
from repro.core.subgraph import Subgraph, textualize
from repro.data.scenegraph import QAItem
from repro.data.tokenizer import Tokenizer
from repro.gnn.projector import apply_projector
from repro.rag.retriever import RetrieverIndex
from repro.serving.engine import ServingEngine
from repro.serving.metrics import QueryRecord, RunSummary

PREFIX_HEADER = "graph :"
QUESTION_HEADER = "question :"
ANSWER_HEADER = "answer :"


@dataclasses.dataclass
class GraphRAGPipeline:
    index: RetrieverIndex
    retriever: object                   # GRetrieverRetriever | GRAGRetriever
    engine: ServingEngine
    tokenizer: Tokenizer
    gnn_params: Optional[dict] = None
    gnn_apply: Optional[Callable] = None
    proj_params: Optional[dict] = None
    use_soft_prompt: bool = True

    # ------------------------------------------------------------------
    def prefix_text(self, sg: Subgraph) -> str:
        return f"{PREFIX_HEADER}\n{textualize(sg, self.index.graph.node_text)}"

    def suffix_text(self, question: str) -> str:
        return f"{QUESTION_HEADER} {question} {ANSWER_HEADER}"

    def soft_prompt(self, sg: Subgraph) -> Optional[np.ndarray]:
        if not (self.use_soft_prompt and self.proj_params is not None):
            return None
        x, snd, rcv, ef = subgraph_tensors(self.index, sg)
        h = self.gnn_apply(self.gnn_params, x, snd, rcv, ef)
        import jax.numpy as jnp
        pooled = jnp.mean(h, axis=0)
        return np.asarray(apply_projector(self.proj_params, pooled))

    def _check(self, generated: str, answer: str) -> bool:
        return answer.lower().strip() in generated.lower()

    # ------------------------------------------------------------------
    def retrieve_all(self, items: Sequence[QAItem]):
        subgraphs, times = [], []
        for it in items:
            t0 = time.perf_counter()
            sg = self.retriever.retrieve(it.question)
            times.append(time.perf_counter() - t0)
            subgraphs.append(sg)
        return subgraphs, times

    # ------------------------------------------------------------------
    def run_baseline(self, items: Sequence[QAItem]) -> tuple:
        """Per-query processing (paper's G-Retriever / GRAG baseline)."""
        subgraphs, ret_times = self.retrieve_all(items)
        records = []
        for it, sg, rt in zip(items, subgraphs, ret_times):
            t0 = time.perf_counter()
            soft = self.soft_prompt(sg)
            prompt = self.prefix_text(sg) + " " + self.suffix_text(it.question)
            toks = self.tokenizer.encode(prompt, bos=True)
            t_build = time.perf_counter() - t0
            out, t = self.engine.generate(toks, soft)
            text = self.tokenizer.decode(out)
            records.append(QueryRecord(
                query=it.question, answer=it.answer, generated=text,
                correct=self._check(text, it.answer), retrieval_s=rt,
                prompt_build_s=t_build, prefill_s=t["prefill_s"],
                decode_s=t["decode_s"], prompt_tokens=len(toks)))
        summary = RunSummary.from_records("baseline", records)
        return records, summary

    # ------------------------------------------------------------------
    def run_subgcache(self, items: Sequence[QAItem], num_clusters: int,
                      linkage: str = "ward") -> tuple:
        """Cluster-wise prefix-cache processing (the paper's method)."""
        subgraphs, ret_times = self.retrieve_all(items)

        t0 = time.perf_counter()
        if self.gnn_params is not None:
            emb = embed_subgraphs(self.index, subgraphs, self.gnn_params,
                                  self.gnn_apply)
        else:  # fall back to text-space pooled embeddings
            emb = np.stack([
                np.mean(self.index.node_vecs[sorted(sg.nodes)], axis=0)
                for sg in subgraphs])
        plan = plan_batch(subgraphs, emb, num_clusters, linkage)
        cluster_time = (time.perf_counter() - t0
                        + plan.cluster_processing_time_s)
        share = cluster_time / max(1, len(items))

        # the engine records cluster/member token accounting into its
        # cache manager as it serves; start a fresh window for this run
        stats = self.engine.cache_mgr.reset_stats()
        records: List[QueryRecord] = [None] * len(items)  # type: ignore
        for cp in plan.clusters:
            t0 = time.perf_counter()
            rep = cp.representative
            soft = self.soft_prompt(rep)
            prefix_tokens = self.tokenizer.encode(self.prefix_text(rep),
                                                  bos=True)
            t_build_prefix = time.perf_counter() - t0

            state, t_prefix = self.engine.prefill_prefix(prefix_tokens, soft)
            n = len(cp.member_indices)

            suffixes, builds = [], []
            for qi in cp.member_indices:
                t1 = time.perf_counter()
                suffixes.append(
                    self.tokenizer.encode(self.suffix_text(items[qi].question)))
                builds.append(time.perf_counter() - t1)

            with self.engine.cache_mgr.cluster(state):
                outs, t = self.engine.generate_with_prefix(state, suffixes)

            for k, qi in enumerate(cp.member_indices):
                it = items[qi]
                text = self.tokenizer.decode(outs[k])
                member_prompt = len(prefix_tokens) + len(suffixes[k])
                records[qi] = QueryRecord(
                    query=it.question, answer=it.answer, generated=text,
                    correct=self._check(text, it.answer),
                    retrieval_s=ret_times[qi], cluster_share_s=share,
                    prompt_build_s=builds[k] + t_build_prefix / n,
                    prefix_share_s=t_prefix / n,
                    prefill_s=t["prefill_s"] / n,
                    decode_s=t["decode_s"] / n,
                    prompt_tokens=member_prompt,
                    cached_tokens=state.prefix_len)
        summary = RunSummary.from_records(
            f"subgcache(c={num_clusters},{linkage})", records,
            cluster_processing_s=cluster_time,
            prefill_savings=stats.prefill_savings)
        return records, summary, plan, stats
