"""Workbench: dataset + tokenizer + trained small backbone + pipeline.

All paper-table benchmarks share this substrate.  The backbone is a small
llama-family model trained ON the RAG task (graph prompt + question ->
answer), then FROZEN — matching the paper's inference-only setting where
the LLM is frozen and G-Retriever/GRAG condition it on retrieved
subgraphs.  Training prompts mix per-query subgraphs with merged
(representative-style) subgraphs so neither serving mode is favored.
Checkpoints cache to results/ so benchmarks re-run instantly.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.subgraph import Subgraph, merge_subgraphs, textualize
from repro.data.oag import generate_oag
from repro.data.scenegraph import QAItem, generate_scene_graph
from repro.data.tokenizer import EOS, Tokenizer
from repro.gnn.gat import apply_gat, init_gat
from repro.gnn.graph_transformer import (apply_graph_transformer,
                                         init_graph_transformer)
from repro.gnn.projector import init_projector
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import (GRAGRetriever, GRetrieverRetriever,
                                 RetrieverIndex)
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine
from repro.serving.metrics import compose_report, tier_report, tree_report
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.train_loop import train as run_train

GNN_DIM = 64
RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def backbone_config(vocab_size: int) -> ModelConfig:
    return ModelConfig(
        name="paper-small", family="dense", num_layers=4, d_model=192,
        num_heads=6, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=vocab_size, dtype="float32", tie_embeddings=True,
        scan_layers=False)


@dataclasses.dataclass
class Workbench:
    dataset: str
    graph: object
    queries: List[QAItem]
    tokenizer: Tokenizer
    cfg: ModelConfig
    params: dict
    index: RetrieverIndex
    gnn_params: dict
    gnn_apply: object
    proj_params: dict

    def pipeline(self, retriever: str = "gretriever",
                 max_new_tokens: int = 8,
                 use_soft_prompt: bool = True) -> GraphRAGPipeline:
        if retriever == "gretriever":
            ret = GRetrieverRetriever(self.index)
        elif retriever == "grag":
            ret = GRAGRetriever(self.index)
        else:
            raise ValueError(retriever)
        eng = ServingEngine(self.params, self.cfg, self.tokenizer,
                            max_cache_len=4096,
                            max_new_tokens=max_new_tokens)
        return GraphRAGPipeline(
            index=self.index, retriever=ret, engine=eng,
            tokenizer=self.tokenizer, gnn_params=self.gnn_params,
            gnn_apply=self.gnn_apply, proj_params=self.proj_params,
            use_soft_prompt=use_soft_prompt)


def serving_report(pipe: GraphRAGPipeline, router=None) -> dict:
    """Engine-recorded SubGCache accounting for the pipeline's current
    stats window (the engine updates ``cache_mgr.stats`` as it serves;
    ``run_subgcache`` resets the window per run).  ``prefill_savings``
    is the paper's headline ratio: tokens a vanilla pipeline would
    prefill over tokens actually prefilled.  Pass the ``ReplicaRouter``
    a ``serve_stream(replicas=N)`` call returned to append the
    per-replica placement/balance breakdown (DESIGN.md §13)."""
    st = pipe.engine.cache_mgr.stats
    out = {
        "num_queries": st.num_queries,
        "num_clusters": st.num_clusters,
        "clusters_split": st.clusters_split,
        "prefix_tokens_computed": st.prefix_tokens_computed,
        "suffix_tokens_computed": st.suffix_tokens_computed,
        "prefill_tokens_baseline": st.prefill_tokens_baseline,
        "prefill_savings": round(st.prefill_savings, 4),
        # observed path, not engine capability: True only when every
        # recorded cluster actually took the cascade
        "split_prefix": (st.num_clusters > 0
                         and st.clusters_split == st.num_clusters),
        # pooled online serving (zeros for the offline pipeline)
        "pool_hits": st.pool_hits,
        "pool_misses": st.pool_misses,
        "pool_evictions": st.pool_evictions,
        "pool_reprefills": st.pool_reprefills,
        "pool_hit_rate": round(st.pool_hit_rate, 4),
        # paged block pool (zeros when the dense backend served)
        "blocks_total": st.blocks_total,
        "blocks_peak": st.blocks_peak,
        "block_occupancy": round(st.block_occupancy, 4),
        "block_fragmentation": round(st.block_fragmentation, 4),
        # prefix-tree chains (DESIGN.md §10; empty levels = flat serving)
        "tree": tree_report(st),
        # host tier (DESIGN.md §12; all-zero when no tier is attached)
        "tier": tier_report(st),
        # segment composition + drift recompute (DESIGN.md §14/§15)
        "compose": compose_report(st),
    }
    if router is not None:
        from repro.serving.metrics import router_report
        out["router"] = router_report(router)
    return out


def _dataset(name: str):
    if name == "scene":
        return generate_scene_graph()
    if name == "oag":
        # compact OAG keeps CPU retrieval + training fast while preserving
        # the heterogeneous structure (paper uses 1071 nodes / 3434 qs)
        return generate_oag(num_papers=160, num_authors=80, num_queries=800)
    raise ValueError(name)


def _make_training_batches(graph, items, tok: Tokenizer, index,
                           retriever, rng: np.random.Generator,
                           batch_size: int, seq_len: int, num_steps: int):
    """Prompt/answer LM batches; 30% use merged multi-query subgraphs."""
    subs = [retriever.retrieve(q.question) for q in items]

    def sample():
        i = int(rng.integers(0, len(items)))
        it = items[i]
        u = rng.random()
        if u < 0.5:
            sg = subs[i]
        else:
            # representative-style merged prompts (up to 8-way) so the
            # backbone is in-distribution for SubGCache cluster prompts
            hi = 4 if u < 0.8 else 9
            js = rng.integers(0, len(items), size=int(rng.integers(2, hi)))
            sg = merge_subgraphs([subs[i]] + [subs[int(j)] for j in js])
        prompt = (f"graph :\n{textualize(sg, graph.node_text)} "
                  f"question : {it.question} answer :")
        p_ids = tok.encode(prompt, bos=True)
        a_ids = tok.encode(" " + it.answer, eos=True)
        ids = (p_ids + a_ids)[:seq_len]
        labels = [0] * len(ids)
        mask = [0.0] * len(ids)
        for j in range(max(0, len(p_ids) - 1),
                       min(len(ids) - 1, len(p_ids) + len(a_ids) - 1)):
            labels[j] = ids[j + 1]
            mask[j] = 1.0
        pad = seq_len - len(ids)
        return (ids + [0] * pad, labels + [0] * pad, mask + [0.0] * pad)

    for _ in range(num_steps):
        rows = [sample() for _ in range(batch_size)]
        yield {
            "tokens": jnp.asarray([r[0] for r in rows], jnp.int32),
            "labels": jnp.asarray([r[1] for r in rows], jnp.int32),
            "mask": jnp.asarray([r[2] for r in rows], jnp.float32),
        }


def build_workbench(dataset: str = "scene", train_steps: int = 300,
                    seed: int = 0, force_retrain: bool = False,
                    log_fn=print) -> Workbench:
    graph, queries = _dataset(dataset)
    full_graph_text = textualize(
        Subgraph.from_lists(range(graph.num_nodes), graph.edges),
        graph.node_text)
    corpus = [full_graph_text, "graph : question : answer :"]
    corpus += [q.question + " " + q.answer for q in queries]
    tok = Tokenizer.train(corpus, max_vocab=8192)
    cfg = backbone_config(tok.vocab_size)

    enc = TextEncoder(GNN_DIM)
    index = RetrieverIndex.build(graph, enc)
    gnn_key = jax.random.PRNGKey(7)
    if dataset == "oag":
        gnn_params = init_gat(gnn_key, GNN_DIM, GNN_DIM, 4, 4)
        gnn_apply = apply_gat
    else:
        gnn_params = init_graph_transformer(gnn_key, GNN_DIM, GNN_DIM, 4, 4)
        gnn_apply = apply_graph_transformer
    proj = init_projector(jax.random.PRNGKey(8), GNN_DIM, cfg.d_model, 1)

    path = os.path.join(RESULTS_DIR, f"backbone_{dataset}.npz")
    params_like = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(seed), cfg))
    if os.path.exists(path) and not force_retrain:
        params, meta = ckpt.load(path, params_like)
        log_fn(f"[workbench] loaded cached backbone {path} "
               f"(loss {meta.get('final_loss'):.3f})")
    else:
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        rng = np.random.default_rng(seed)
        ret = GRetrieverRetriever(index)
        train_items = queries[: max(64, len(queries) // 2)]
        batches = _make_training_batches(
            graph, train_items, tok, index, ret, rng,
            batch_size=8, seq_len=576, num_steps=train_steps)
        ocfg = opt.AdamWConfig(learning_rate=3e-3, weight_decay=0.01,
                               warmup_steps=20)
        params, hist = run_train(params, cfg, ocfg, batches, train_steps,
                                 log_every=50, log_fn=log_fn)
        ckpt.save(path, params,
                  {"final_loss": hist[-1]["loss"] if hist else None,
                   "dataset": dataset, "steps": train_steps})
        log_fn(f"[workbench] saved backbone to {path}")
    return Workbench(dataset=dataset, graph=graph, queries=queries,
                     tokenizer=tok, cfg=cfg, params=params, index=index,
                     gnn_params=gnn_params, gnn_apply=gnn_apply,
                     proj_params=proj)


def test_items(wb: Workbench, n: int = 100, seed: int = 123) -> List[QAItem]:
    """Held-out in-batch query sample (paper: random 100 test queries)."""
    rng = np.random.default_rng(seed)
    pool = wb.queries[len(wb.queries) // 2:]
    idx = rng.choice(len(pool), size=min(n, len(pool)), replace=False)
    return [pool[int(i)] for i in idx]
