"""Textual graph: the external knowledge source of graph-based RAG."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.subgraph import Edge, Subgraph


@dataclasses.dataclass
class TextGraph:
    node_text: List[str]                 # node attribute strings
    edges: List[Edge]                    # (src, rel_text, dst)

    def __post_init__(self):
        self._adj: Dict[int, List[Tuple[int, str, int]]] = {}
        for e in self.edges:
            s, r, d = e
            self._adj.setdefault(s, []).append(e)
            self._adj.setdefault(d, []).append(e)

    @property
    def num_nodes(self) -> int:
        return len(self.node_text)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def incident_edges(self, node: int) -> List[Edge]:
        return self._adj.get(node, [])

    def neighbors(self, node: int) -> Set[int]:
        out = set()
        for s, _, d in self.incident_edges(node):
            out.add(d if s == node else s)
        return out

    def ego_subgraph(self, center: int, hops: int,
                     node_whitelist: Set[int] | None = None) -> Subgraph:
        """k-hop ego network around ``center`` (GRAG-style retrieval unit)."""
        frontier = {center}
        nodes = {center}
        edges: Set[Edge] = set()
        for _ in range(hops):
            nxt = set()
            for n in frontier:
                for e in self.incident_edges(n):
                    s, _, d = e
                    other = d if s == n else s
                    if node_whitelist is not None and other not in node_whitelist:
                        continue
                    edges.add(e)
                    if other not in nodes:
                        nxt.add(other)
            nodes |= nxt
            frontier = nxt
        return Subgraph.from_lists(nodes, edges)

    def bfs_path(self, src: int, dst: int) -> List[Edge]:
        """Shortest path edge list (for PCST-lite connectivity repair)."""
        if src == dst:
            return []
        prev: Dict[int, Edge] = {}
        seen = {src}
        queue = [src]
        while queue:
            cur = queue.pop(0)
            for e in self.incident_edges(cur):
                s, _, d = e
                other = d if s == cur else s
                if other in seen:
                    continue
                seen.add(other)
                prev[other] = e
                if other == dst:
                    path = []
                    node = dst
                    while node != src:
                        e2 = prev[node]
                        path.append(e2)
                        node = e2[0] if e2[2] == node else e2[2]
                    return list(reversed(path))
                queue.append(other)
        return []

    def edge_text(self, e: Edge) -> str:
        s, r, d = e
        return f"{self.node_text[s]} {r} {self.node_text[d]}"
