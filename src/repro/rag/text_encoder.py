"""SentenceBERT stand-in: deterministic hashed bag-of-words text encoder.

Plays SentenceBERT's role in the pipeline (App. A.2): embeds node/edge
attribute strings and queries into a shared vector space for retrieval
scoring and GNN input features.  Implementation: each word hashes to a
fixed Gaussian direction (stable across processes via blake2), texts are
mean-pooled and L2-normalized.  Lexically similar texts land close —
sufficient for the retrieval substrate, with zero external weights.
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Sequence

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+")


class TextEncoder:
    def __init__(self, dim: int = 128):
        self.dim = dim
        self._cache: dict = {}

    def _word_vec(self, word: str) -> np.ndarray:
        v = self._cache.get(word)
        if v is None:
            seed = int.from_bytes(
                hashlib.blake2b(word.encode(), digest_size=8).digest(), "little")
            rng = np.random.default_rng(seed)
            v = rng.standard_normal(self.dim).astype(np.float32)
            v /= np.linalg.norm(v) + 1e-8
            self._cache[word] = v
        return v

    def encode(self, texts: Sequence[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            words = _TOKEN_RE.findall(t.lower())
            if not words:
                continue
            v = np.mean([self._word_vec(w) for w in words], axis=0)
            n = np.linalg.norm(v)
            out[i] = v / (n + 1e-8)
        return out

    def encode_one(self, text: str) -> np.ndarray:
        return self.encode([text])[0]


def cosine_scores(query_vec: np.ndarray, mat: np.ndarray) -> np.ndarray:
    return mat @ query_vec
