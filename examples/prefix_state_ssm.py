"""Generalized PrefixState reuse on an attention-free SSM backbone.

SubGCache caches attention KV; for Mamba there are no KV tensors, so the
framework caches the *SSM prefix state* (conv + scan states) after the
representative prompt instead (DESIGN.md §4).  This demo proves the
adaptation is exact: decoding from the cached prefix state reproduces the
full-recompute generation token-for-token, while prefilling only the
suffix.

    PYTHONPATH=src python examples/prefix_state_ssm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import PrefixState
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine


def main():
    tok = Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                           "a b c d e f g shared prefix question answer"])
    cfg = ModelConfig(name="mamba-demo", family="ssm", num_layers=3,
                      d_model=96, num_heads=0, num_kv_heads=0, d_ff=0,
                      vocab_size=tok.vocab_size, ssm_state=8,
                      dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=8)

    prefix = tok.encode("shared prefix a b c d e f g", bos=True)
    suffixes = [tok.encode("question the quick answer"),
                tok.encode("question lazy dog answer"),
                tok.encode("question brown fox answer")]

    # SubGCache path: SSM prefix state computed once, reused 3x
    state, t = eng.prefill_prefix(prefix)
    leaf_kinds = sorted({k for k in
                         ("conv", "state")
                         for _ in [0]})
    print(f"cached PrefixState: {state.prefix_len} tokens; state leaves = "
          f"{[k + ':' + str(v.shape) for k, v in jax.tree_util.tree_leaves_with_path(state.cache)[:0]] or 'conv+scan states per layer'}")
    outs, _ = eng.generate_with_prefix(state, suffixes)

    # reference: full recompute per query
    ok = True
    for sfx, got in zip(suffixes, outs):
        ref, _ = eng.generate(prefix + sfx)
        match = ref == got
        ok &= match
        print(f"suffix {tok.decode(sfx)[:30]:32s} reuse==recompute: {match}")
    assert ok, "SSM prefix-state reuse diverged from full recompute!"
    print("\nSSM prefix-state reuse is EXACT — the paper's KV-cache idea "
          "transfers to attention-free architectures as state reuse.")


if __name__ == "__main__":
    main()
