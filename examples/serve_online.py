"""Online SubGCache serving: streaming queries, pooled prefixes, TTFT.

Where ``quickstart.py`` plans one offline batch, this demo replays a
Poisson arrival trace through ``GraphRAGPipeline.serve_stream``
(DESIGN.md §7/§9): queries are assigned to clusters incrementally
(spawning on distance > threshold) and served against a byte-budgeted
``PrefixPool`` of representative-prefix KV caches by the CONTINUOUS
in-flight batch (the default mode): arrivals admit into free slots
between fixed-size decode chunks and rows retire the moment they emit
EOS — pass ``mode="drain"`` to A/B against the drain-serve loop.
Reports TTFT per query (including arrival-queue wait) and the pool
hit/miss/eviction counters.

    PYTHONPATH=src python examples/serve_online.py
"""
import jax
import numpy as np

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine


def main():
    graph, queries = generate_scene_graph()
    print(f"textual graph: {graph.num_nodes} nodes / {graph.num_edges} edges; "
          f"{len(queries)} queries")

    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="demo", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    enc = TextEncoder(64)
    index = RetrieverIndex.build(graph, enc)
    retriever = GRetrieverRetriever(index)
    engine = ServingEngine(params, cfg, tok, max_cache_len=512,
                           max_new_tokens=8)
    pipe = GraphRAGPipeline(index=index, retriever=retriever, engine=engine,
                            tokenizer=tok, use_soft_prompt=False)

    items = queries[:16]
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(0.05, size=len(items)))

    # compile the full (batch, page-width) bucket grid up front — online
    # micro-batch composition depends on arrival dynamics, and on the
    # paged backend every page-table width bucket is its own compiled
    # shape, so warm one representative per width the trace spans or a
    # multi-second XLA compile lands inside a reported TTFT
    # (EXPERIMENTS.md protocol)
    rep_lens = sorted({len(tok.encode(
        pipe.prefix_text(retriever.retrieve(it.question)), bos=True))
        for it in items})
    engine.warmup_pooled(rep_lens, batches=(1, 2, 4), num_prefixes=(1, 2, 4))
    # warm the continuous-mode (admission batch, page width) grid —
    # online composition depends on arrival dynamics, so any bucket can
    # appear at any moment — then one untimed replay to warm the pool
    pipe.warmup_stream(items, max_batch=4, prefix_lens=rep_lens)
    pipe.serve_stream(items, arrivals, max_batch=4, threshold=0.25,
                      pool_budget_bytes=1 << 26)

    records, summary, sched = pipe.serve_stream(
        items, arrivals, max_batch=4, threshold=0.25,
        pool_budget_bytes=1 << 26)
    print(summary.row())
    stats = sched.pool.stats
    print(f"clusters spawned: {len(sched.assigner.clusters)}  "
          f"pool: {stats.pool_hits} hits / {stats.pool_misses} misses "
          f"(hit rate {stats.pool_hit_rate:.0%}), "
          f"{stats.pool_evictions} evictions, "
          f"{stats.pool_reprefills} re-prefills, "
          f"{sched.pool.bytes_in_use / 2**20:.1f} MiB pooled")
    for r in records[:4]:
        print(f"  wait {r.queue_wait_s*1e3:7.1f}ms  "
              f"ttft {r.ttft*1e3:7.1f}ms  cached {r.cached_tokens} tok  "
              f"q: {r.query[:48]}")


if __name__ == "__main__":
    main()
