"""Replica serving cluster: cluster-affinity routing over N engines.

Where ``serve_online.py`` streams a Poisson trace into ONE
``ServingEngine``, this demo serves the same kind of trace across a
2-replica cluster through ``serve_stream(replicas=2)`` (DESIGN.md §13):
a ``ReplicaRouter`` pins every cluster to exactly one replica (so its
representative prefix is resident on exactly one device), spawns fresh
clusters on the least-loaded replica, and — when the load imbalance
crosses ``hot_ratio`` — migrates a drained co-located cluster to the
coldest replica through the host tier (demote → move → re-admit;
promotion happens lazily on the cluster's next query).

Token streams are identical to a single-replica run on a cold trace:
one shared ``OnlineClusterAssigner`` is consulted in global arrival
order, and greedy decoding depends only on (prefix, suffix, params) —
placement and batching never change the math.

    PYTHONPATH=src python examples/serve_replicas.py
"""
import jax
import numpy as np

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine
from repro.serving.metrics import router_report


def main():
    graph, queries = generate_scene_graph()
    print(f"textual graph: {graph.num_nodes} nodes / {graph.num_edges} edges; "
          f"{len(queries)} queries")

    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="demo", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    enc = TextEncoder(64)
    index = RetrieverIndex.build(graph, enc)
    retriever = GRetrieverRetriever(index)
    engine = ServingEngine(params, cfg, tok, max_cache_len=512,
                           max_new_tokens=8)
    pipe = GraphRAGPipeline(index=index, retriever=retriever, engine=engine,
                            tokenizer=tok, use_soft_prompt=False)

    items = queries[:16]
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(0.05, size=len(items)))

    records, summary, router = pipe.serve_stream(
        list(items), list(arrivals), replicas=2, max_batch=4,
        threshold=0.25, pool_budget_bytes=1 << 26, mode="drain")
    print(summary.row())

    report = router_report(router, records)
    print(f"router: {report['num_replicas']} replicas, "
          f"{report['clusters']} clusters placed, "
          f"imbalance {report['imbalance']:.2f}, "
          f"{report['migrations']} migrations")
    for idx, rep in sorted(report["replicas"].items()):
        print(f"  replica {idx}: routed {rep['routed']:2d}  "
              f"spawns {rep['spawns']}  "
              f"affinity {rep['affinity_hit_rate']:.0%}  "
              f"pool hit rate {rep['pool_hit_rate']:.0%}  "
              f"occupancy {rep['block_occupancy']:.0%}")
    for r in records[:4]:
        print(f"  replica {r.replica}  wait {r.queue_wait_s*1e3:7.1f}ms  "
              f"ttft {r.ttft*1e3:7.1f}ms  cached {r.cached_tokens} tok  "
              f"q: {r.query[:48]}")


if __name__ == "__main__":
    main()
