"""End-to-end driver: train a small backbone, freeze it, and serve an
in-batch graph-RAG workload with and without SubGCache (paper Table 2).

    PYTHONPATH=src python examples/serve_inbatch_rag.py \
        [--dataset scene|oag] [--num-queries 100] [--clusters 2]
"""
import argparse

from repro.rag.workbench import build_workbench, test_items
from repro.serving.metrics import speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene", choices=["scene", "oag"])
    ap.add_argument("--num-queries", type=int, default=100)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--retriever", default="gretriever",
                    choices=["gretriever", "grag"])
    ap.add_argument("--train-steps", type=int, default=300)
    args = ap.parse_args()

    wb = build_workbench(args.dataset, train_steps=args.train_steps)
    items = test_items(wb, args.num_queries)
    pipe = wb.pipeline(args.retriever)
    print("warming up compiled shape buckets ...")
    pipe.engine.warmup()

    print(f"\n=== vanilla {args.retriever} (per-query) ===")
    rb, sb = pipe.run_baseline(items)
    print(sb.row())

    print(f"\n=== +SubGCache (c={args.clusters}) ===")
    rs, ss, plan, stats = pipe.run_subgcache(items,
                                             num_clusters=args.clusters)
    print(ss.row())
    print(f"clusters: {[len(c.member_indices) for c in plan.clusters]}")
    sp = speedup(sb, ss)
    print(f"\nACC delta {sp['acc_delta']:+.2f} | RT x{sp['rt_x']:.2f} | "
          f"TTFT x{sp['ttft_x']:.2f} | PFTT x{sp['pftt_x']:.2f} | "
          f"prefill-token savings x{stats.prefill_savings:.2f}")

    # a couple of sample generations
    for r in rs[:3]:
        print(f"\nQ: {r.query}\n   gold: {r.answer}\n   gen:  {r.generated}"
              f"  [{'OK' if r.correct else 'X'}]")


if __name__ == "__main__":
    main()
