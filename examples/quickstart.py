"""Quickstart: the SubGCache pipeline end-to-end in one minute (no training).

Builds the Scene Graph dataset, retrieves subgraphs for a small in-batch
query set, clusters them with the pretrained-GNN embeddings, constructs
representative subgraphs, and serves every query through the prefix-cache
engine with a randomly-initialized tiny backbone (mechanics demo —
see serve_inbatch_rag.py for the trained-ACC version).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.planner import plan_batch
from repro.core.embedding import embed_subgraphs
from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.gnn.graph_transformer import (apply_graph_transformer,
                                         init_graph_transformer)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine


def main():
    graph, queries = generate_scene_graph()
    print(f"textual graph: {graph.num_nodes} nodes / {graph.num_edges} edges; "
          f"{len(queries)} queries")

    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="demo", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    enc = TextEncoder(64)
    index = RetrieverIndex.build(graph, enc)
    retriever = GRetrieverRetriever(index)
    gnn = init_graph_transformer(jax.random.PRNGKey(1), 64, 64, 2, 4)

    items = queries[:16]
    subs = [retriever.retrieve(q.question) for q in items]
    emb = embed_subgraphs(index, subs, gnn, apply_graph_transformer)
    plan = plan_batch(subs, emb, num_clusters=3)
    print(f"clusters: {[len(c.member_indices) for c in plan.clusters]}"
          f"  (reuse factor x{plan.reuse_factor:.1f}, "
          f"planned in {plan.cluster_processing_time_s*1e3:.1f}ms)")
    for c in plan.clusters:
        print(f"  cluster {c.cluster_id}: {len(c.member_indices)} queries, "
              f"representative subgraph {c.representative.num_nodes}n/"
              f"{c.representative.num_edges}e")

    engine = ServingEngine(params, cfg, tok, max_cache_len=512,
                           max_new_tokens=8)
    pipe = GraphRAGPipeline(index=index, retriever=retriever, engine=engine,
                            tokenizer=tok, gnn_params=gnn,
                            gnn_apply=apply_graph_transformer,
                            use_soft_prompt=False)
    _, summary, plan, stats = pipe.run_subgcache(items, num_clusters=3)
    print(summary.row())
    print(f"prefill token savings vs per-query baseline: "
          f"x{stats.prefill_savings:.2f} "
          f"({stats.prefill_tokens_baseline} -> {stats.prefill_tokens_cached}"
          f" tokens)")


if __name__ == "__main__":
    main()
