"""Train the G-Retriever-style soft-prompt projector against the FROZEN
backbone (the paper's training protocol: LLM frozen, GNN+projector
trained; App. A.2), using the repo's own AdamW + train loop.

    PYTHONPATH=src python examples/train_gretriever.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import subgraph_tensors
from repro.gnn.projector import apply_projector
from repro.models import model as M
from repro.rag.retriever import GRetrieverRetriever
from repro.rag.workbench import build_workbench
from repro.training import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dataset", default="scene")
    args = ap.parse_args()

    wb = build_workbench(args.dataset, train_steps=300)
    retr = GRetrieverRetriever(wb.index)
    items = wb.queries[:128]
    tok, cfg = wb.tokenizer, wb.cfg
    rng = np.random.default_rng(0)

    # Precompute per-query (graph tensors, prompt ids, answer ids)
    data = []
    for it in items:
        sg = retr.retrieve(it.question)
        x, snd, rcv, ef = subgraph_tensors(wb.index, sg)
        from repro.core.subgraph import textualize
        prompt = (f"graph :\n{textualize(sg, wb.graph.node_text)} "
                  f"question : {it.question} answer :")
        p_ids = tok.encode(prompt, bos=True)
        a_ids = tok.encode(" " + it.answer, eos=True)
        data.append((x, snd, rcv, ef, p_ids, a_ids))

    gnn_apply = wb.gnn_apply
    llm_params = wb.params              # FROZEN

    def loss_fn(trainable, sample):
        gx, snd, rcv, ef, p_ids, a_ids = sample
        h = gnn_apply(trainable["gnn"], gx, snd, rcv, ef)
        soft = apply_projector(trainable["proj"], jnp.mean(h, axis=0))
        ids = jnp.asarray(p_ids + a_ids, jnp.int32)[None]
        emb = M.embed_tokens(llm_params, ids)
        emb = jnp.concatenate([soft[None].astype(emb.dtype), emb], axis=1)
        t = emb.shape[1]
        pos = jnp.arange(t, dtype=jnp.int32)[None]
        hid, _, _ = M.forward(llm_params, cfg, emb, pos)
        logits = M.unembed(llm_params, cfg, hid)
        n_soft = soft.shape[0]
        labels = jnp.zeros((1, t), jnp.int32)
        mask = jnp.zeros((1, t), jnp.float32)
        start = n_soft + len(p_ids) - 1
        for j, a in enumerate(a_ids):
            labels = labels.at[0, start + j].set(a)
            mask = mask.at[0, start + j].set(1.0)
        return M.lm_loss(llm_params, cfg, logits, labels, mask)

    trainable = {"gnn": wb.gnn_params, "proj": wb.proj_params}
    state = opt.init_state(trainable)
    ocfg = opt.AdamWConfig(learning_rate=1e-3, weight_decay=0.01)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    print(f"training GNN+projector against the frozen {cfg.name} backbone")
    ema = None
    for step in range(args.steps):
        sample = data[int(rng.integers(0, len(data)))]
        loss, grads = grad_fn(trainable, sample)
        trainable, state, _ = opt.apply_updates(trainable, grads, state, ocfg)
        ema = float(loss) if ema is None else 0.95 * ema + 0.05 * float(loss)
        if (step + 1) % 25 == 0:
            print(f"step {step+1:4d}  loss(ema) {ema:.4f}")
    print("done — projector trained while the LLM stayed frozen.")


if __name__ == "__main__":
    main()
